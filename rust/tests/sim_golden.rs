//! Golden sim-semantics equivalence: the optimized (arena, allocation-free,
//! event-driven) simulator core must reproduce the pre-refactor simulator's
//! metrics **bit-for-bit** on fixed workloads.
//!
//! The pre-refactor semantics are preserved verbatim in
//! `medha::sim::reference::ReferenceSimulation` (map-based store,
//! per-iteration allocations, O(n²) retain, 1e-6 s idle bumps). Both cores
//! run the same deterministic workloads; every summary statistic — finished
//! count, TTFT/TBT percentiles, throughput, utilization means — and the
//! total simulated time must compare exactly equal as f64s, not within a
//! tolerance: the refactor changed the engineering of the loop, not the
//! simulated behavior.

use medha::config::DeploymentConfig;
use medha::metrics::MetricsSummary;
use medha::sim::reference::ReferenceSimulation;
use medha::sim::{SimOptions, Simulation};
use medha::workload::{self, LengthDist, RequestSpec};

struct RunOutcome {
    end_s: f64,
    n_iters: u64,
    summary: MetricsSummary,
    onboard_log: Vec<(f64, u64, u32)>,
    group_busy_s: Vec<f64>,
    group_prefill_tokens: Vec<u64>,
    group_decode_tokens: Vec<u64>,
}

fn run_optimized(dep: DeploymentConfig, w: Vec<RequestSpec>) -> RunOutcome {
    let mut sim = Simulation::new(dep, w, SimOptions::default());
    let end_s = sim.run();
    RunOutcome {
        end_s,
        n_iters: sim.metrics.n_iters,
        onboard_log: sim.kvp_onboard_log().to_vec(),
        group_busy_s: sim.metrics.group_busy_s.clone(),
        group_prefill_tokens: sim.metrics.group_prefill_tokens.clone(),
        group_decode_tokens: sim.metrics.group_decode_tokens.clone(),
        summary: sim.metrics.summary(),
    }
}

fn run_reference(dep: DeploymentConfig, w: Vec<RequestSpec>) -> RunOutcome {
    let mut sim = ReferenceSimulation::new(dep, w, SimOptions::default());
    let end_s = sim.run();
    RunOutcome {
        end_s,
        n_iters: sim.metrics.n_iters,
        onboard_log: sim.kvp_onboard_log().to_vec(),
        group_busy_s: sim.metrics.group_busy_s.clone(),
        group_prefill_tokens: sim.metrics.group_prefill_tokens.clone(),
        group_decode_tokens: sim.metrics.group_decode_tokens.clone(),
        summary: sim.metrics.summary(),
    }
}

/// Exact f64 comparison (NaN == NaN so empty-population statistics match).
fn assert_f64_identical(what: &str, a: f64, b: f64) {
    assert!(
        a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()),
        "{what}: optimized {a:?} != reference {b:?}"
    );
}

fn assert_outcomes_identical(opt: &RunOutcome, reference: &RunOutcome) {
    assert_eq!(opt.summary.finished, reference.summary.finished, "finished");
    assert_eq!(opt.n_iters, reference.n_iters, "iteration count");
    assert_eq!(opt.summary.n_ttft, reference.summary.n_ttft, "n_ttft");
    assert_eq!(opt.summary.n_tbt, reference.summary.n_tbt, "n_tbt");
    assert_eq!(opt.onboard_log, reference.onboard_log, "kvp onboard log");
    assert_f64_identical("end time", opt.end_s, reference.end_s);
    assert_f64_identical("ttft_p50", opt.summary.ttft_p50, reference.summary.ttft_p50);
    assert_f64_identical("ttft_p95", opt.summary.ttft_p95, reference.summary.ttft_p95);
    assert_f64_identical("tbt_p50", opt.summary.tbt_p50, reference.summary.tbt_p50);
    assert_f64_identical("tbt_p95", opt.summary.tbt_p95, reference.summary.tbt_p95);
    assert_f64_identical("tbt_p99", opt.summary.tbt_p99, reference.summary.tbt_p99);
    assert_f64_identical("tbt_max", opt.summary.tbt_max, reference.summary.tbt_max);
    assert_f64_identical("decode_tps", opt.summary.decode_tps, reference.summary.decode_tps);
    assert_f64_identical("mfu_mean", opt.summary.mfu_mean, reference.summary.mfu_mean);
    assert_f64_identical("mbu_mean", opt.summary.mbu_mean, reference.summary.mbu_mean);
    // SLO-attainment accounting must also agree bit-for-bit: both cores
    // assign the same length-aware deadlines at admission and judge the
    // same finish times against them.
    assert_f64_identical(
        "ttft_attainment",
        opt.summary.ttft_attainment,
        reference.summary.ttft_attainment,
    );
    assert_f64_identical(
        "tbt_attainment",
        opt.summary.tbt_attainment,
        reference.summary.tbt_attainment,
    );
    assert_f64_identical("goodput_rps", opt.summary.goodput_rps, reference.summary.goodput_rps);
    // FCFS never preempts: both cores must report zero, and active yields
    // cannot exist outside the pooled routing modes.
    assert_eq!(opt.summary.preemptions, 0, "optimized FCFS preempted");
    assert_eq!(reference.summary.preemptions, 0, "reference preempted");
    assert_eq!(opt.summary.active_preemptions, 0, "optimized yielded an active request");
    assert_eq!(reference.summary.active_preemptions, 0, "reference yielded");
    // Capacity-refused admissions only exist under routed placement with a
    // finite KV capacity; blind mode must mirror the reference's zero.
    assert_eq!(opt.summary.routing_refusals, 0, "optimized blind mode refused a placement");
    assert_eq!(reference.summary.routing_refusals, 0, "reference refused a placement");
    // per-group utilization accounting, bit-for-bit
    assert_eq!(opt.group_busy_s.len(), reference.group_busy_s.len(), "group count");
    for (g, (a, b)) in opt.group_busy_s.iter().zip(&reference.group_busy_s).enumerate() {
        assert_f64_identical(&format!("group {g} busy_s"), *a, *b);
    }
    assert_eq!(
        opt.group_prefill_tokens, reference.group_prefill_tokens,
        "group prefill tokens"
    );
    assert_eq!(
        opt.group_decode_tokens, reference.group_decode_tokens,
        "group decode tokens"
    );
}

/// Workload 1: fixed-seed Poisson mix of short requests across two KVP
/// groups, adaptive chunking on — exercises routing, continuous batching,
/// and idle-gap handling.
#[test]
fn golden_mixed_short_poisson() {
    let dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 2);
    let w = workload::poisson_mixed(
        8.0,
        30.0,
        LengthDist::ZipfBuckets {
            buckets: vec![128, 1_024, 4_096, 12_288],
            s: 1.1,
        },
        16,
        42,
    );
    assert!(w.len() > 100, "workload degenerate: {} requests", w.len());
    let opt = run_optimized(dep.clone(), w.clone());
    let reference = run_reference(dep, w);
    assert!(opt.summary.finished > 100);
    assert_outcomes_identical(&opt, &reference);
}

/// Workload 2: one long KVP-sharded request (dynamic onboarding across 4
/// groups) batched alongside short decodes — exercises cooperative
/// iterations, the KVP merge charge, adaptive chunk shrinking, and the
/// onboarding staircase.
#[test]
fn golden_long_kvp_sharded_plus_decodes() {
    let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 2, 4);
    dep.scheduler.kvp_onboard_threshold = 256_000;
    let w = workload::long_plus_decodes(1_000_000, 8, 1_000, 64);
    let opt = run_optimized(dep.clone(), w.clone());
    let reference = run_reference(dep, w);
    assert_eq!(opt.summary.finished, 9);
    assert_eq!(opt.onboard_log.len(), 4, "expected 4 KVP onboard events");
    assert_outcomes_identical(&opt, &reference);
}

/// Static chunking variant of workload 2 — the chunk policy out of the
/// loop isolates batch formation and pipeline-flow equivalence.
#[test]
fn golden_long_static_chunking() {
    let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 2);
    dep.scheduler.adaptive_chunking = false;
    dep.scheduler.static_chunk = 2048;
    let w = workload::long_plus_decodes(200_000, 6, 1_000, 32);
    let opt = run_optimized(dep.clone(), w.clone());
    let reference = run_reference(dep, w);
    assert_outcomes_identical(&opt, &reference);
}

/// Workload 4: the kvp_convoy trace — overlapping KVP-sharded documents
/// plus interactive traffic across 4 groups — under FCFS with the default
/// blind routing. The routed modes change semantics deliberately; this
/// anchor pins that FCFS-without-routing on the *same trace* stays
/// bit-identical to the oracle.
#[test]
fn golden_kvp_convoy_fcfs_blind() {
    let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 4);
    dep.scheduler.adaptive_chunking = false;
    dep.scheduler.static_chunk = 4096;
    dep.scheduler.kvp_onboard_threshold = 256_000;
    let cfg = workload::KvpConvoyConfig::default();
    let w = workload::kvp_convoy(&cfg, 42);
    let opt = run_optimized(dep.clone(), w.clone());
    let reference = run_reference(dep, w);
    assert!(opt.summary.finished > 100);
    assert_outcomes_identical(&opt, &reference);
}

/// Exact f64 equality over every summary statistic — NaN == NaN, like the
/// oracle comparison above.
fn assert_summaries_bit_identical(a: &MetricsSummary, b: &MetricsSummary) {
    assert_eq!(a.n_ttft, b.n_ttft);
    assert_eq!(a.n_tbt, b.n_tbt);
    assert_eq!(a.finished, b.finished);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.active_preemptions, b.active_preemptions);
    assert_eq!(a.routing_refusals, b.routing_refusals);
    for (what, x, y) in [
        ("ttft_p50", a.ttft_p50, b.ttft_p50),
        ("ttft_p95", a.ttft_p95, b.ttft_p95),
        ("tbt_p50", a.tbt_p50, b.tbt_p50),
        ("tbt_p95", a.tbt_p95, b.tbt_p95),
        ("tbt_p99", a.tbt_p99, b.tbt_p99),
        ("tbt_max", a.tbt_max, b.tbt_max),
        ("decode_tps", a.decode_tps, b.decode_tps),
        ("mfu_mean", a.mfu_mean, b.mfu_mean),
        ("mbu_mean", a.mbu_mean, b.mbu_mean),
        ("ttft_attainment", a.ttft_attainment, b.ttft_attainment),
        ("tbt_attainment", a.tbt_attainment, b.tbt_attainment),
        ("goodput_rps", a.goodput_rps, b.goodput_rps),
    ] {
        assert_f64_identical(what, x, y);
    }
}

/// Determinism regression for the new pooled semantics: same workload seed
/// + same policy ⇒ bit-identical `MetricsSummary`, onboarding log, and
/// preemption-event stream across two routed runs, for all four policies.
#[test]
fn kvp_routed_runs_are_bit_deterministic() {
    use medha::coordinator::{RoutingMode, SchedPolicyKind};
    let cfg = workload::KvpConvoyConfig {
        horizon_s: 15.0,
        doc_prompt: 128_000,
        n_docs: 2,
        doc_stagger_s: 6.0,
        ..workload::KvpConvoyConfig::default()
    };
    for kind in SchedPolicyKind::ALL {
        let mut a = medha::sim::run_kvp_convoy_scenario(kind, RoutingMode::Routed, &cfg, 7);
        let mut b = medha::sim::run_kvp_convoy_scenario(kind, RoutingMode::Routed, &cfg, 7);
        assert_eq!(a.metrics.n_iters, b.metrics.n_iters, "{}", kind.name());
        assert_eq!(a.metrics.preemption_events, b.metrics.preemption_events);
        assert_eq!(a.kvp_onboard_log(), b.kvp_onboard_log());
        assert_eq!(a.metrics.group_prefill_tokens, b.metrics.group_prefill_tokens);
        let (sa, sb) = (a.metrics.summary(), b.metrics.summary());
        assert_summaries_bit_identical(&sa, &sb);
    }
}
