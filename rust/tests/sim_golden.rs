//! Recorded golden snapshots: the simulator's metric stream must be
//! **bit-deterministic**, and its semantics must not drift silently.
//!
//! The pre-PR-5 repo enforced this by maintaining a second simulator core
//! (`sim::reference`, the map-based pre-arena implementation) and
//! asserting bit-identical metrics against it — double-maintenance that
//! every semantic change had to pay twice. With the cores unified on the
//! single pool-scheduled `Simulation::step`, determinism is enforced by
//! **recorded golden snapshots** instead:
//!
//! * every golden scenario runs **twice** in-process and the two runs'
//!   full outcome serializations (every summary statistic as exact f64
//!   bits, per-group token/busy accounting, the KVP onboarding log, the
//!   simulated end time) must be identical — bit-determinism across runs;
//! * the serialization is then compared against a snapshot file under
//!   `rust/tests/golden/`. The first run in an environment **records** the
//!   snapshot (committing it pins the semantics for every run after);
//!   set `MEDHA_BLESS=1` to deliberately re-record after an intentional
//!   semantics change.
//!
//! The blind-mode lockstep equivalence (the old `step_lockstep` path that
//! PR 5 folded into the pooled step as the all-groups-cooperate barrier)
//! is additionally proven structurally: on a single-group deployment the
//! barrier and the pool arm must coincide, so `blind` and `round-robin`
//! runs must be bit-identical there — a cross-arm differential that needs
//! no second core.

use std::fs;
use std::path::PathBuf;

use medha::config::{DeploymentConfig, FaultEvent, FaultKind, FaultPlan};
use medha::coordinator::{GroupState, RoutingMode, SchedPolicyKind};
use medha::sim::{
    kvp_convoy_dep, run_convoy_scenario, run_kvp_convoy_scenario,
    run_kvp_convoy_scenario_with_faults, run_multiturn_scenario, SimOptions, Simulation,
};
use medha::workload::{self, LengthDist, RequestSpec};

/// Exact, human-auditable serialization of everything a golden scenario
/// pins: f64s are rendered as their raw bit patterns (plus a readable
/// decimal), so comparison is bit-exact by construction — including NaNs
/// for empty-population statistics.
fn serialize_outcome(sim: &mut Simulation, end_s: f64) -> String {
    let mut out = String::new();
    let mut f = |name: &str, x: f64| {
        out.push_str(&format!("{name} = {:016x} ({x:?})\n", x.to_bits()));
    };
    f("end_s", end_s);
    let n_iters = sim.metrics.n_iters;
    let group_busy = sim.metrics.group_busy_s.clone();
    let group_prefill = sim.metrics.group_prefill_tokens.clone();
    let group_decode = sim.metrics.group_decode_tokens.clone();
    let onboard = sim.kvp_onboard_log().to_vec();
    let n_events = sim.metrics.preemption_events.len();
    let s = sim.metrics.summary();
    f("ttft_p50", s.ttft_p50);
    f("ttft_p95", s.ttft_p95);
    f("tbt_p50", s.tbt_p50);
    f("tbt_p95", s.tbt_p95);
    f("tbt_p99", s.tbt_p99);
    f("tbt_max", s.tbt_max);
    f("decode_tps", s.decode_tps);
    f("mfu_mean", s.mfu_mean);
    f("mbu_mean", s.mbu_mean);
    f("ttft_attainment", s.ttft_attainment);
    f("tbt_attainment", s.tbt_attainment);
    f("goodput_rps", s.goodput_rps);
    f("deferral_wait_p95", s.deferral_wait_p95);
    f("recovery_wait_p50", s.recovery_wait_p50);
    f("recovery_wait_p95", s.recovery_wait_p95);
    f("prefix_hit_rate", s.prefix_hit_rate);
    for (g, b) in group_busy.iter().enumerate() {
        f(&format!("group{g}_busy_s"), *b);
    }
    out.push_str(&format!("n_iters = {n_iters}\n"));
    out.push_str(&format!("n_ttft = {}\n", s.n_ttft));
    out.push_str(&format!("n_tbt = {}\n", s.n_tbt));
    out.push_str(&format!("finished = {}\n", s.finished));
    out.push_str(&format!("preemptions = {}\n", s.preemptions));
    out.push_str(&format!("active_preemptions = {}\n", s.active_preemptions));
    out.push_str(&format!("routing_refusals = {}\n", s.routing_refusals));
    out.push_str(&format!("n_deferred = {}\n", s.n_deferred));
    out.push_str(&format!("group_crashes = {}\n", s.group_crashes));
    out.push_str(&format!("shards_lost = {}\n", s.shards_lost));
    out.push_str(&format!("reprefill_tokens = {}\n", s.reprefill_tokens));
    out.push_str(&format!("kv_overcommit_tokens = {}\n", s.kv_overcommit_tokens));
    out.push_str(&format!("prefix_hit_tokens = {}\n", s.prefix_hit_tokens));
    out.push_str(&format!("blocks_shared = {}\n", s.blocks_shared));
    out.push_str(&format!(
        "reprefill_shared_tokens = {}\n",
        s.reprefill_shared_tokens
    ));
    out.push_str(&format!(
        "n_shed = {} (short {} / doc {})\n",
        s.n_shed, s.n_shed_short, s.n_shed_doc
    ));
    out.push_str(&format!(
        "n_rejected_queue_full = {} (short {} / doc {})\n",
        s.n_rejected_queue_full, s.n_rejected_short, s.n_rejected_doc
    ));
    out.push_str(&format!("n_recovered = {}\n", s.n_recovered));
    out.push_str(&format!("n_preemption_events = {n_events}\n"));
    out.push_str(&format!("group_prefill_tokens = {group_prefill:?}\n"));
    out.push_str(&format!("group_decode_tokens = {group_decode:?}\n"));
    out.push_str(&format!("n_onboard_events = {}\n", onboard.len()));
    for (t, id, g) in onboard {
        out.push_str(&format!(
            "onboard = {:016x} ({t:?}) req={id} group={g}\n",
            t.to_bits()
        ));
    }
    out
}

fn snapshot_path(name: &str) -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden"))
        .join(format!("{name}.snap"))
}

/// Compare `content` against the recorded snapshot, recording it when
/// absent (first run in a fresh environment) or when `MEDHA_BLESS` is set.
///
/// With `MEDHA_REQUIRE_SNAPSHOTS=1` a missing snapshot is a **failure**
/// instead of a recording: CI runs the suite a second time under this
/// flag (same workspace, so the first pass's recordings are present),
/// guaranteeing the compare path actually executes everywhere — a
/// record-only harness would pass trivially on every fresh checkout.
fn assert_matches_snapshot(name: &str, content: &str) {
    let path = snapshot_path(name);
    let bless = std::env::var("MEDHA_BLESS").is_ok();
    if !bless && !path.exists() && std::env::var("MEDHA_REQUIRE_SNAPSHOTS").is_ok() {
        panic!(
            "golden snapshot {} is missing under MEDHA_REQUIRE_SNAPSHOTS — \
             record it (plain `cargo test --test sim_golden`) and commit it",
            path.display()
        );
    }
    if bless || !path.exists() {
        fs::create_dir_all(path.parent().unwrap()).expect("create golden dir");
        fs::write(&path, content).expect("record golden snapshot");
        if !bless {
            eprintln!("recorded new golden snapshot {}", path.display());
        }
        return;
    }
    let recorded = fs::read_to_string(&path).expect("read golden snapshot");
    assert_eq!(
        recorded, content,
        "snapshot {name} diverged from {} — if the semantics change is \
         intentional, re-record with MEDHA_BLESS=1",
        path.display()
    );
}

/// Run a scenario twice, assert the two outcomes bit-identical (the
/// determinism half), then pin the serialization against the recorded
/// snapshot (the no-silent-drift half).
fn golden<F: Fn() -> (Simulation, f64)>(name: &str, run: F) -> Simulation {
    let (mut a, end_a) = run();
    let (mut b, end_b) = run();
    let sa = serialize_outcome(&mut a, end_a);
    let sb = serialize_outcome(&mut b, end_b);
    assert_eq!(sa, sb, "{name}: two identical runs diverged (non-determinism)");
    assert_matches_snapshot(name, &sa);
    a
}

/// Workload 1: fixed-seed Poisson mix of short requests across two KVP
/// groups, adaptive chunking on — exercises routing, continuous batching,
/// and idle-gap handling under the default blind FCFS configuration.
#[test]
fn golden_mixed_short_poisson() {
    let w = workload::poisson_mixed(
        8.0,
        30.0,
        LengthDist::ZipfBuckets {
            buckets: vec![128, 1_024, 4_096, 12_288],
            s: 1.1,
        },
        16,
        42,
    );
    assert!(w.len() > 100, "workload degenerate: {} requests", w.len());
    let mut sim = golden("mixed_short_poisson", || {
        let dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 2);
        let mut sim = Simulation::new(dep, w.clone(), SimOptions::default());
        let end = sim.run();
        (sim, end)
    });
    assert!(sim.metrics.summary().finished > 100);
    // capacity is sized to the workload here: the ledger must never absorb
    // tokens past a group's free room
    assert_eq!(sim.metrics.kv_overcommit_tokens, 0);
}

/// Workload 2: one long KVP-sharded request (dynamic onboarding across 4
/// groups) batched alongside short decodes — exercises cooperative
/// iterations, the KVP merge charge, adaptive chunk shrinking, and the
/// onboarding staircase.
#[test]
fn golden_long_kvp_sharded_plus_decodes() {
    let mut sim = golden("long_kvp_sharded_plus_decodes", || {
        let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 2, 4);
        dep.scheduler.kvp_onboard_threshold = 256_000;
        let w = workload::long_plus_decodes(1_000_000, 8, 1_000, 64);
        let mut sim = Simulation::new(dep, w, SimOptions::default());
        let end = sim.run();
        (sim, end)
    });
    assert_eq!(sim.metrics.summary().finished, 9);
    assert_eq!(sim.kvp_onboard_log().len(), 4, "expected 4 KVP onboard events");
    assert_eq!(sim.metrics.kv_overcommit_tokens, 0);
}

/// Static chunking variant of workload 2 — the chunk policy out of the
/// loop isolates batch formation and pipeline-flow determinism.
#[test]
fn golden_long_static_chunking() {
    golden("long_static_chunking", || {
        let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 2);
        dep.scheduler.adaptive_chunking = false;
        dep.scheduler.static_chunk = 2048;
        let w = workload::long_plus_decodes(200_000, 6, 1_000, 32);
        let mut sim = Simulation::new(dep, w, SimOptions::default());
        let end = sim.run();
        (sim, end)
    });
}

/// The heterogeneous convoy trace under blind FCFS — the scheduling
/// anchor: documents and interactive requests through one per-group queue.
#[test]
fn golden_convoy_fcfs_blind() {
    let cfg = workload::ConvoyConfig::default();
    let mut sim = golden("convoy_fcfs_blind", || {
        let sim = run_convoy_scenario(SchedPolicyKind::Fcfs, &cfg, 42);
        let end = sim.metrics.span_s();
        (sim, end)
    });
    assert!(sim.metrics.summary().finished > 100);
}

/// The kvp_convoy trace — overlapping KVP-sharded documents plus
/// interactive traffic across 4 groups — under FCFS with the default
/// blind routing. The routed modes change semantics deliberately; this
/// anchor pins unified-blind FCFS on the *same trace* the pooled modes
/// run.
#[test]
fn golden_kvp_convoy_fcfs_blind() {
    let cfg = workload::KvpConvoyConfig::default();
    let mut sim = golden("kvp_convoy_fcfs_blind", || {
        let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 4);
        dep.scheduler.adaptive_chunking = false;
        dep.scheduler.static_chunk = 4096;
        dep.scheduler.kvp_onboard_threshold = 256_000;
        let w = workload::kvp_convoy(&cfg, 42);
        let mut sim = Simulation::new(dep, w, SimOptions::default());
        let end = sim.run();
        (sim, end)
    });
    assert!(sim.metrics.summary().finished > 100);
    assert_eq!(sim.metrics.kv_overcommit_tokens, 0);
}

/// The full policy × routing matrix on a reduced kvp_convoy trace: every
/// combination must be bit-deterministic across runs and pinned by its
/// own recorded snapshot — the single unified core means every one of
/// these exercises the same `Simulation::step`.
#[test]
fn golden_policy_routing_matrix() {
    let cfg = workload::KvpConvoyConfig {
        horizon_s: 15.0,
        doc_prompt: 128_000,
        n_docs: 2,
        doc_stagger_s: 6.0,
        ..workload::KvpConvoyConfig::default()
    };
    for kind in SchedPolicyKind::ALL {
        for routing in RoutingMode::ALL {
            let name = format!("kvp_convoy_{}_{}", kind.name(), routing.name());
            golden(&name, || {
                let sim = run_kvp_convoy_scenario(kind, routing, &cfg, 7);
                let end = sim.metrics.span_s();
                (sim, end)
            });
        }
    }
}

/// Structural lockstep-equivalence proof for the folded blind mode.
///
/// On a **single-group** deployment the blind barrier (all groups
/// cooperate) and the pool arm (only shard holders cooperate; everyone
/// else iterates independently) describe the same schedule: one group,
/// one clock. The pre-refactor `step_lockstep` was exactly the barrier
/// schedule, so `blind` must be bit-identical to `round-robin` here —
/// across all four policies on the convoy trace (no sharded path), and
/// under FCFS with a genuinely KVP-sharded document (single group holds
/// every shard). This replaces the retired `sim::reference` oracle with a
/// differential the unified core carries inside itself.
#[test]
fn unified_blind_is_lockstep_on_one_group() {
    // (a) convoy-style heterogeneous trace, everything through the group
    // scheduler (long_threshold = MAX), all four policies.
    let cfg = workload::ConvoyConfig {
        horizon_s: 20.0,
        long_every: 10, // keep documents in the short 20 s trace
        ..workload::ConvoyConfig::default()
    };
    let w = workload::convoy(&cfg, 11);
    for kind in SchedPolicyKind::ALL {
        let run = |routing: RoutingMode| -> String {
            let mut dep = DeploymentConfig::llama3_8b_tp8();
            dep.scheduler.policy = kind;
            dep.scheduler.routing = routing;
            dep.scheduler.adaptive_chunking = false;
            let opts = SimOptions {
                long_threshold: u64::MAX,
                ..SimOptions::default()
            };
            let mut sim = Simulation::new(dep, w.clone(), opts);
            let end = sim.run();
            serialize_outcome(&mut sim, end)
        };
        assert_eq!(
            run(RoutingMode::Blind),
            run(RoutingMode::RoundRobin),
            "{}: blind (barrier) != round-robin (pool) on one group",
            kind.name()
        );
    }
    // (b) a genuinely sharded document alongside decodes, FCFS: the
    // cooperative path with its merge-free single-holder iteration.
    let run_sharded = |routing: RoutingMode| -> String {
        let mut dep = DeploymentConfig::llama3_8b_tp8();
        dep.scheduler.routing = routing;
        dep.scheduler.adaptive_chunking = false;
        dep.scheduler.static_chunk = 2048;
        dep.scheduler.kvp_onboard_threshold = 50_000;
        let w = workload::long_plus_decodes(100_000, 8, 1_000, 32);
        let mut sim = Simulation::new(dep, w, SimOptions::default());
        let end = sim.run();
        serialize_outcome(&mut sim, end)
    };
    assert_eq!(
        run_sharded(RoutingMode::Blind),
        run_sharded(RoutingMode::RoundRobin),
        "fcfs sharded: blind (barrier) != round-robin (pool) on one group"
    );
}

/// Same-tick arrival regression carried over from the oracle era: the
/// golden workloads must be insensitive to trace construction order (the
/// `(arrival, id)` pending sort), or snapshots would flap between hosts.
#[test]
fn golden_workloads_are_construction_order_insensitive() {
    let mut w = workload::long_plus_decodes(200_000, 6, 1_000, 32);
    let run = |w: Vec<RequestSpec>| -> String {
        let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 2);
        dep.scheduler.adaptive_chunking = false;
        dep.scheduler.static_chunk = 2048;
        let mut sim = Simulation::new(dep, w, SimOptions::default());
        let end = sim.run();
        serialize_outcome(&mut sim, end)
    };
    let forward = run(w.clone());
    w.reverse();
    let reversed = run(w);
    assert_eq!(forward, reversed, "admission order leaked trace construction order");
}

/// Fault-injection goldens: a mid-run group crash — and a crash followed
/// by a warmed-up rejoin — must be exactly as bit-deterministic as the
/// fault-free scenarios, recovery placement, chunk-boundary re-prefill,
/// and degradation accounting included. The crash instant is derived from
/// a fault-free probe run (just after a mid-run KVP onboard event, aimed
/// at the group that onboarded) so document shards are resident when the
/// group dies, without hard-coding perf-model timings.
#[test]
fn golden_fault_crash_and_rejoin() {
    let cfg = workload::KvpConvoyConfig {
        horizon_s: 15.0,
        doc_prompt: 128_000,
        n_docs: 2,
        doc_stagger_s: 6.0,
        ..workload::KvpConvoyConfig::default()
    };
    let probe = run_kvp_convoy_scenario_with_faults(
        SchedPolicyKind::Lars,
        RoutingMode::Routed,
        &cfg,
        7,
        FaultPlan::default(),
    );
    let log = probe.kvp_onboard_log();
    assert!(!log.is_empty(), "probe run never sharded a document");
    let (t_mid, _, victim) = log[log.len() / 2];
    let crash_t = t_mid + 0.25;

    // (a) crash only: the fleet stays degraded for the rest of the run
    let mut sim = golden("kvp_convoy_lars_routed_crash", || {
        let plan = FaultPlan {
            events: vec![FaultEvent {
                t_s: crash_t,
                group: Some(victim),
                kind: FaultKind::Crash,
            }],
        };
        let sim =
            run_kvp_convoy_scenario_with_faults(SchedPolicyKind::Lars, RoutingMode::Routed, &cfg, 7, plan);
        let end = sim.metrics.span_s();
        (sim, end)
    });
    let s = sim.metrics.summary();
    assert_eq!(s.group_crashes, 1);
    assert!(s.shards_lost > 0, "crash instant missed resident shards");
    assert!(s.reprefill_tokens > 0);
    assert_eq!(sim.group_state(victim), GroupState::Down);
    assert!(sim.kvp_ledger_is_conserved());

    // (b) the same crash followed by a warmed-up rejoin of the dead group
    let sim = golden("kvp_convoy_lars_routed_crash_join", || {
        let plan = FaultPlan {
            events: vec![
                FaultEvent {
                    t_s: crash_t,
                    group: Some(victim),
                    kind: FaultKind::Crash,
                },
                FaultEvent {
                    t_s: crash_t + 2.0,
                    group: Some(victim),
                    kind: FaultKind::Join { warmup_s: 0.5 },
                },
            ],
        };
        let sim =
            run_kvp_convoy_scenario_with_faults(SchedPolicyKind::Lars, RoutingMode::Routed, &cfg, 7, plan);
        let end = sim.metrics.span_s();
        (sim, end)
    });
    assert_eq!(sim.group_state(victim), GroupState::Active, "rejoin must restore the group");
    assert_eq!(sim.n_active_groups(), 4);
    assert!(sim.kvp_ledger_is_conserved());
}

/// Open-loop golden scenarios: every `serve-sim` scenario under both the
/// pass-through gate (must shadow the closed loop bit-exactly — the same
/// serialization the closed-loop goldens pin) and the protective gate
/// (token-bucket pacing + bounded queues + SLO-feedback shedding, whose
/// drop accounting the extended serialization now pins). Each runs twice
/// in-process (bit-determinism) before the snapshot compare, like every
/// other golden.
#[test]
fn golden_openloop_scenarios() {
    use medha::coordinator::AdmissionConfig;
    use medha::sim::serve::run_serve_scenario;
    use medha::workload::openloop::{OpenLoopConfig, Scenario};

    let cfg = OpenLoopConfig {
        base_rate_per_s: 6.0,
        horizon_s: 12.0,
        doc_prompt: 65_536,
        doc_every: 24,
        ..OpenLoopConfig::default()
    };
    for scenario in [Scenario::Flash, Scenario::Diurnal, Scenario::Overcommit] {
        for (gate_name, gate) in [
            ("pass", AdmissionConfig::default()),
            (
                "protective",
                AdmissionConfig::protective(cfg.base_rate_per_s, cfg.doc_prompt),
            ),
        ] {
            let name = format!("openloop_{}_{gate_name}", scenario.name());
            golden(&name, || {
                let mut serve = run_serve_scenario(
                    scenario,
                    &cfg,
                    SchedPolicyKind::Lars,
                    RoutingMode::Routed,
                    gate.clone(),
                    7,
                );
                let end = serve.sim.metrics.span_s();
                (serve.sim, end)
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Parallel-step determinism: `scheduler.threads > 1` shards per-group
// phase-A work across the pool and merges in group-index order; every
// scenario below must serialize bit-identically to its threads=1 run.
// ---------------------------------------------------------------------------

/// The reduced kvp_convoy trace used by the thread-matrix tests: two
/// KVP-sharded documents plus interactive traffic over a 10 s horizon —
/// enough to exercise cooperative iterations, onboarding, routing
/// refusals, and preemption under every policy without full-trace cost.
fn thread_matrix_cfg() -> workload::KvpConvoyConfig {
    workload::KvpConvoyConfig {
        horizon_s: 10.0,
        doc_prompt: 96_000,
        n_docs: 2,
        doc_stagger_s: 4.0,
        ..workload::KvpConvoyConfig::default()
    }
}

/// Run the kvp_convoy scenario with an explicit worker-thread count (the
/// scenario helpers always use the config default of 1).
fn run_kvp_convoy_threads(
    kind: SchedPolicyKind,
    routing: RoutingMode,
    cfg: &workload::KvpConvoyConfig,
    seed: u64,
    threads: usize,
    faults: FaultPlan,
) -> String {
    let mut dep = kvp_convoy_dep(kind, routing, cfg);
    dep.scheduler.threads = threads;
    let w = workload::kvp_convoy(cfg, seed);
    let opts = SimOptions {
        faults,
        ..SimOptions::default()
    };
    let mut sim = Simulation::new(dep, w, opts);
    sim.run();
    let end = sim.metrics.span_s();
    serialize_outcome(&mut sim, end)
}

/// Tentpole determinism contract, fault-free half: the full policy ×
/// routing matrix at threads = 2 and 4 must be bit-identical to serial.
#[test]
fn parallel_step_matches_serial_policy_routing_matrix() {
    let cfg = thread_matrix_cfg();
    for kind in SchedPolicyKind::ALL {
        for routing in RoutingMode::ALL {
            let serial = run_kvp_convoy_threads(kind, routing, &cfg, 7, 1, FaultPlan::default());
            for threads in [2usize, 4] {
                let par = run_kvp_convoy_threads(kind, routing, &cfg, 7, threads, FaultPlan::default());
                assert_eq!(
                    serial,
                    par,
                    "{} x {}: threads={threads} diverged from serial",
                    kind.name(),
                    routing.name()
                );
            }
        }
    }
}

/// Tentpole determinism contract, fault half: a mid-run crash followed by
/// a warmed-up rejoin (the probe-derived plan from
/// `golden_fault_crash_and_rejoin`) must survive the parallel step
/// bit-identically — elastic-fleet transitions happen between instants,
/// outside the sharded phase.
#[test]
fn parallel_step_matches_serial_under_faults() {
    let cfg = workload::KvpConvoyConfig {
        horizon_s: 15.0,
        doc_prompt: 128_000,
        n_docs: 2,
        doc_stagger_s: 6.0,
        ..workload::KvpConvoyConfig::default()
    };
    let probe = run_kvp_convoy_scenario_with_faults(
        SchedPolicyKind::Lars,
        RoutingMode::Routed,
        &cfg,
        7,
        FaultPlan::default(),
    );
    let log = probe.kvp_onboard_log();
    assert!(!log.is_empty(), "probe run never sharded a document");
    let (t_mid, _, victim) = log[log.len() / 2];
    let plan = FaultPlan {
        events: vec![
            FaultEvent {
                t_s: t_mid + 0.25,
                group: Some(victim),
                kind: FaultKind::Crash,
            },
            FaultEvent {
                t_s: t_mid + 2.25,
                group: Some(victim),
                kind: FaultKind::Join { warmup_s: 0.5 },
            },
        ],
    };
    let run = |threads: usize| {
        run_kvp_convoy_threads(
            SchedPolicyKind::Lars,
            RoutingMode::Routed,
            &cfg,
            7,
            threads,
            plan.clone(),
        )
    };
    let serial = run(1);
    for threads in [2usize, 4] {
        assert_eq!(serial, run(threads), "fault scenario diverged at threads={threads}");
    }
}

/// Blind barrier and genuinely sharded shapes under the parallel step:
/// the Poisson short mix on a 2-group blind deployment with adaptive
/// chunking (golden workload 1's shape), and the 1M-token KVP-sharded
/// document beside decodes on 4 groups (golden workload 2's shape).
#[test]
fn parallel_step_matches_serial_blind_and_sharded() {
    // (a) blind + adaptive chunking, 2 groups
    let w = workload::poisson_mixed(
        8.0,
        15.0,
        LengthDist::ZipfBuckets {
            buckets: vec![128, 1_024, 4_096, 12_288],
            s: 1.1,
        },
        16,
        42,
    );
    let run_blind = |threads: usize| -> String {
        let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 2);
        dep.scheduler.threads = threads;
        let mut sim = Simulation::new(dep, w.clone(), SimOptions::default());
        let end = sim.run();
        serialize_outcome(&mut sim, end)
    };
    // (b) one KVP-sharded long request + lockstep decodes, 4 groups
    let run_sharded = |threads: usize| -> String {
        let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 2, 4);
        dep.scheduler.kvp_onboard_threshold = 256_000;
        dep.scheduler.threads = threads;
        let w = workload::long_plus_decodes(1_000_000, 8, 1_000, 64);
        let mut sim = Simulation::new(dep, w, SimOptions::default());
        let end = sim.run();
        serialize_outcome(&mut sim, end)
    };
    let blind_serial = run_blind(1);
    let sharded_serial = run_sharded(1);
    for threads in [2usize, 4] {
        assert_eq!(blind_serial, run_blind(threads), "blind mix diverged at threads={threads}");
        assert_eq!(
            sharded_serial,
            run_sharded(threads),
            "sharded long diverged at threads={threads}"
        );
    }
}

/// The multi-turn prefix-reuse scenario with the index ON (LARS + routed
/// cache-affinity placement): the reuse machinery — content-hashed chain
/// lookup, refcount lifecycle, shared-ledger accounting, LRU eviction —
/// must be bit-deterministic across runs and pinned by its own snapshot.
#[test]
fn golden_multiturn_lars_routed_reuse() {
    let cfg = workload::MultiTurnConfig::default();
    let mut sim = golden("multiturn_lars_routed_reuse", || {
        let sim = run_multiturn_scenario(SchedPolicyKind::Lars, RoutingMode::Routed, &cfg, 42, true);
        let end = sim.metrics.span_s();
        (sim, end)
    });
    let s = sim.metrics.summary();
    assert!(s.finished > 50, "degenerate multiturn trace: {}", s.finished);
    assert!(s.prefix_hit_rate > 0.0, "affinity arm must hit the index");
    assert!(sim.prefix_index_is_consistent());
    assert!(sim.kvp_ledger_is_conserved());
}

/// The same trace under FCFS + blind placement with the index ON: grants
/// happen only on coincidental owner-group landings, and the blind
/// lockstep barrier must stay bit-deterministic with reuse in the loop.
#[test]
fn golden_multiturn_fcfs_blind_reuse() {
    let cfg = workload::MultiTurnConfig::default();
    let mut sim = golden("multiturn_fcfs_blind_reuse", || {
        let sim = run_multiturn_scenario(SchedPolicyKind::Fcfs, RoutingMode::Blind, &cfg, 42, true);
        let end = sim.metrics.span_s();
        (sim, end)
    });
    assert!(sim.metrics.summary().finished > 50);
    assert!(sim.prefix_index_is_consistent());
    assert!(sim.kvp_ledger_is_conserved());
}

/// The no-reuse control arm on the same trace: `prefix_reuse = false`
/// must keep every reuse counter at zero — and this snapshot pins that
/// the multiturn trace on the pre-reuse paths never drifts.
#[test]
fn golden_multiturn_lars_routed_noreuse() {
    let cfg = workload::MultiTurnConfig::default();
    let mut sim = golden("multiturn_lars_routed_noreuse", || {
        let sim =
            run_multiturn_scenario(SchedPolicyKind::Lars, RoutingMode::Routed, &cfg, 42, false);
        let end = sim.metrics.span_s();
        (sim, end)
    });
    let s = sim.metrics.summary();
    assert!(s.finished > 50);
    assert_eq!(s.prefix_hit_tokens, 0, "reuse off must never grant");
    assert_eq!(s.blocks_shared, 0);
    assert_eq!(s.reprefill_shared_tokens, 0);
}
