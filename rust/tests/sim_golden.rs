//! Golden sim-semantics equivalence: the optimized (arena, allocation-free,
//! event-driven) simulator core must reproduce the pre-refactor simulator's
//! metrics **bit-for-bit** on fixed workloads.
//!
//! The pre-refactor semantics are preserved verbatim in
//! `medha::sim::reference::ReferenceSimulation` (map-based store,
//! per-iteration allocations, O(n²) retain, 1e-6 s idle bumps). Both cores
//! run the same deterministic workloads; every summary statistic — finished
//! count, TTFT/TBT percentiles, throughput, utilization means — and the
//! total simulated time must compare exactly equal as f64s, not within a
//! tolerance: the refactor changed the engineering of the loop, not the
//! simulated behavior.

use medha::config::DeploymentConfig;
use medha::metrics::MetricsSummary;
use medha::sim::reference::ReferenceSimulation;
use medha::sim::{SimOptions, Simulation};
use medha::workload::{self, LengthDist, RequestSpec};

struct RunOutcome {
    end_s: f64,
    n_iters: u64,
    summary: MetricsSummary,
    onboard_log: Vec<(f64, u64, u32)>,
}

fn run_optimized(dep: DeploymentConfig, w: Vec<RequestSpec>) -> RunOutcome {
    let mut sim = Simulation::new(dep, w, SimOptions::default());
    let end_s = sim.run();
    RunOutcome {
        end_s,
        n_iters: sim.metrics.n_iters,
        onboard_log: sim.kvp_onboard_log().to_vec(),
        summary: sim.metrics.summary(),
    }
}

fn run_reference(dep: DeploymentConfig, w: Vec<RequestSpec>) -> RunOutcome {
    let mut sim = ReferenceSimulation::new(dep, w, SimOptions::default());
    let end_s = sim.run();
    RunOutcome {
        end_s,
        n_iters: sim.metrics.n_iters,
        onboard_log: sim.kvp_onboard_log().to_vec(),
        summary: sim.metrics.summary(),
    }
}

/// Exact f64 comparison (NaN == NaN so empty-population statistics match).
fn assert_f64_identical(what: &str, a: f64, b: f64) {
    assert!(
        a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()),
        "{what}: optimized {a:?} != reference {b:?}"
    );
}

fn assert_outcomes_identical(opt: &RunOutcome, reference: &RunOutcome) {
    assert_eq!(opt.summary.finished, reference.summary.finished, "finished");
    assert_eq!(opt.n_iters, reference.n_iters, "iteration count");
    assert_eq!(opt.summary.n_ttft, reference.summary.n_ttft, "n_ttft");
    assert_eq!(opt.summary.n_tbt, reference.summary.n_tbt, "n_tbt");
    assert_eq!(opt.onboard_log, reference.onboard_log, "kvp onboard log");
    assert_f64_identical("end time", opt.end_s, reference.end_s);
    assert_f64_identical("ttft_p50", opt.summary.ttft_p50, reference.summary.ttft_p50);
    assert_f64_identical("ttft_p95", opt.summary.ttft_p95, reference.summary.ttft_p95);
    assert_f64_identical("tbt_p50", opt.summary.tbt_p50, reference.summary.tbt_p50);
    assert_f64_identical("tbt_p95", opt.summary.tbt_p95, reference.summary.tbt_p95);
    assert_f64_identical("tbt_p99", opt.summary.tbt_p99, reference.summary.tbt_p99);
    assert_f64_identical("tbt_max", opt.summary.tbt_max, reference.summary.tbt_max);
    assert_f64_identical("decode_tps", opt.summary.decode_tps, reference.summary.decode_tps);
    assert_f64_identical("mfu_mean", opt.summary.mfu_mean, reference.summary.mfu_mean);
    assert_f64_identical("mbu_mean", opt.summary.mbu_mean, reference.summary.mbu_mean);
    // SLO-attainment accounting must also agree bit-for-bit: both cores
    // assign the same length-aware deadlines at admission and judge the
    // same finish times against them.
    assert_f64_identical(
        "ttft_attainment",
        opt.summary.ttft_attainment,
        reference.summary.ttft_attainment,
    );
    assert_f64_identical(
        "tbt_attainment",
        opt.summary.tbt_attainment,
        reference.summary.tbt_attainment,
    );
    assert_f64_identical("goodput_rps", opt.summary.goodput_rps, reference.summary.goodput_rps);
    // FCFS never preempts: both cores must report zero.
    assert_eq!(opt.summary.preemptions, 0, "optimized FCFS preempted");
    assert_eq!(reference.summary.preemptions, 0, "reference preempted");
}

/// Workload 1: fixed-seed Poisson mix of short requests across two KVP
/// groups, adaptive chunking on — exercises routing, continuous batching,
/// and idle-gap handling.
#[test]
fn golden_mixed_short_poisson() {
    let dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 2);
    let w = workload::poisson_mixed(
        8.0,
        30.0,
        LengthDist::ZipfBuckets {
            buckets: vec![128, 1_024, 4_096, 12_288],
            s: 1.1,
        },
        16,
        42,
    );
    assert!(w.len() > 100, "workload degenerate: {} requests", w.len());
    let opt = run_optimized(dep.clone(), w.clone());
    let reference = run_reference(dep, w);
    assert!(opt.summary.finished > 100);
    assert_outcomes_identical(&opt, &reference);
}

/// Workload 2: one long KVP-sharded request (dynamic onboarding across 4
/// groups) batched alongside short decodes — exercises cooperative
/// iterations, the KVP merge charge, adaptive chunk shrinking, and the
/// onboarding staircase.
#[test]
fn golden_long_kvp_sharded_plus_decodes() {
    let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 2, 4);
    dep.scheduler.kvp_onboard_threshold = 256_000;
    let w = workload::long_plus_decodes(1_000_000, 8, 1_000, 64);
    let opt = run_optimized(dep.clone(), w.clone());
    let reference = run_reference(dep, w);
    assert_eq!(opt.summary.finished, 9);
    assert_eq!(opt.onboard_log.len(), 4, "expected 4 KVP onboard events");
    assert_outcomes_identical(&opt, &reference);
}

/// Static chunking variant of workload 2 — the chunk policy out of the
/// loop isolates batch formation and pipeline-flow equivalence.
#[test]
fn golden_long_static_chunking() {
    let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 2);
    dep.scheduler.adaptive_chunking = false;
    dep.scheduler.static_chunk = 2048;
    let w = workload::long_plus_decodes(200_000, 6, 1_000, 32);
    let opt = run_optimized(dep.clone(), w.clone());
    let reference = run_reference(dep, w);
    assert_outcomes_identical(&opt, &reference);
}
