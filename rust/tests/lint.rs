//! Tier-1 enforcement of the determinism contract (see `util::lint`):
//! the committed tree must be lint-clean, and each rule must still fire
//! on a fixture of its bug class — so a rule can neither rot into a
//! no-op nor silently accumulate violations.

use std::path::Path;

use medha::util::lint::{check_source, check_tree, count_rs_files, LintConfig, Rule};

fn src_root() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src")
}

fn rules(path: &str, fixture: &str) -> Vec<Rule> {
    check_source(path, fixture, &LintConfig::repo_default())
        .into_iter()
        .map(|f| f.rule)
        .collect()
}

#[test]
fn committed_tree_is_lint_clean() {
    let findings = check_tree(src_root()).expect("scanning rust/src");
    let report: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
    assert!(
        findings.is_empty(),
        "determinism contract violated:\n{}",
        report.join("\n")
    );
}

#[test]
fn tree_scan_actually_covers_the_source() {
    // Guard against the clean-tree test passing vacuously because the
    // root moved: the crate has dozens of source files and must keep
    // having them.
    let n = count_rs_files(src_root()).expect("counting rust/src");
    assert!(n >= 30, "only {n} .rs files under rust/src — wrong root?");
}

#[test]
fn d1_fixture_fires_and_allowlist_holds() {
    let bad = "use std::collections::HashMap;\n";
    assert_eq!(rules("sim/mod.rs", bad), vec![Rule::HashCollections]);
    assert_eq!(rules("workload/mod.rs", bad), vec![Rule::HashCollections]);
    // util substrates are outside the replayable-state scope
    assert!(rules("util/json.rs", bad).is_empty());
}

#[test]
fn d2_fixture_fires_and_allowlist_holds() {
    let bad = "let t0 = std::time::Instant::now();\n";
    assert_eq!(rules("sim/mod.rs", bad), vec![Rule::WallClock]);
    assert_eq!(rules("coordinator/scheduler.rs", bad), vec![Rule::WallClock]);
    // the timing-only modules measure wall clock by design
    for allowed in [
        "util/bench.rs",
        "sim/sweep.rs",
        "sim/throughput.rs",
        "engine/pipeline.rs",
        "util/threadpool.rs",
    ] {
        assert!(rules(allowed, bad).is_empty(), "{allowed} should be allowlisted");
    }
}

#[test]
fn d3_fixture_fires_tree_wide() {
    // the exact comparator shape this PR removed from config/faults.rs
    let bad = "self.events.sort_by(|a, b| a.t_s.partial_cmp(&b.t_s).expect(\"non-finite\"));\n";
    assert_eq!(rules("config/faults.rs", bad), vec![Rule::FloatOrd]);
    assert_eq!(rules("util/stats.rs", bad), vec![Rule::FloatOrd]);
    let good = "self.events.sort_by(|a, b| a.t_s.total_cmp(&b.t_s));\n";
    assert!(rules("config/faults.rs", good).is_empty());
}

#[test]
fn d4_fixture_fires_and_rounded_casts_pass() {
    // the PR 8 p95 bug class, both shapes
    assert_eq!(
        rules("util/stats.rs", "let i = (xs.len() as f64 * 0.95) as usize;\n"),
        vec![Rule::TruncIndex]
    );
    assert_eq!(
        rules("metrics/mod.rs", "let i = xs.len() * 95 / 100;\n"),
        vec![Rule::TruncIndex]
    );
    // explicit rounding is the sanctioned idiom
    assert!(rules("util/stats.rs", "let lo = rank.floor() as usize;\n").is_empty());
    assert!(rules("util/stats.rs", "let hi = rank.ceil() as usize;\n").is_empty());
    // out of scope: bit-mixing in the RNG is not rank arithmetic
    assert!(rules("util/rng.rs", "let i = (x as f64 * 0.5) as usize;\n").is_empty());
}

#[test]
fn u1_fixture_fires_outside_declared_modules_and_without_safety() {
    // the pre-PR runtime raw-parts shape, minus its (new) SAFETY comment
    let raw_parts = "let b = unsafe { std::slice::from_raw_parts(p, n) };\n";
    // outside the declared modules: banned outright
    assert_eq!(rules("sim/mod.rs", raw_parts), vec![Rule::UnsafeHygiene]);
    assert_eq!(rules("kvcache/mod.rs", raw_parts), vec![Rule::UnsafeHygiene]);
    // inside a declared module: allowed only with an adjacent SAFETY note
    assert_eq!(rules("runtime/mod.rs", raw_parts), vec![Rule::UnsafeHygiene]);
    let with_safety = "// SAFETY: p points at n initialized bytes owned by `data`.\n\
                       let b = unsafe { std::slice::from_raw_parts(p, n) };\n";
    assert!(rules("runtime/mod.rs", with_safety).is_empty());
    // sneaking in a module-level opt-out is also a finding
    assert_eq!(
        rules("workload/mod.rs", "#![allow(unsafe_code)]\n"),
        vec![Rule::UnsafeHygiene]
    );
}

#[test]
fn unsafe_appears_only_in_declared_modules_with_safety() {
    // Belt and braces over the clean-tree test: walk the tree ourselves
    // and assert the U1 invariant directly, so the acceptance criterion
    // ("every unsafe has SAFETY, only in the two declared modules") is
    // stated in one place even if scopes are later edited.
    let cfg = LintConfig::repo_default();
    assert_eq!(cfg.unsafe_modules.len(), 2, "declared unsafe modules changed");
    let findings = check_tree(src_root()).expect("scanning rust/src");
    let u1: Vec<String> = findings
        .iter()
        .filter(|f| f.rule == Rule::UnsafeHygiene)
        .map(|f| f.to_string())
        .collect();
    assert!(u1.is_empty(), "unsafe hygiene violations:\n{}", u1.join("\n"));
}
