//! Acceptance tests for the elastic-fleet tentpole: deterministic failure
//! injection on the kvp_convoy trace. The headline guarantee is the
//! paper's title applied to faults — *no request left behind*: a KVP
//! group crash mid-run costs re-prefill work and recovery wait, never a
//! dropped request. The lost shards restart from the last surviving
//! chunk boundary (witnessed through the drop/onboard logs, and through
//! the conservation identity `prefill work = fault-free work +
//! re-prefilled tokens`), and the capacity ledger balances when the run
//! drains. A heavier crash→rejoin storm matrix across every policy runs
//! under `MEDHA_BENCH_SMOKE=1` (the CI fault-matrix job).

use medha::config::{FaultEvent, FaultKind, FaultPlan};
use medha::coordinator::{GroupState, RoutingMode, SchedPolicyKind};
use medha::sim::run_kvp_convoy_scenario_with_faults;
use medha::workload::{self, fault_storm, FaultStormConfig};

fn crash_plan(t_s: f64, group: u32) -> FaultPlan {
    FaultPlan {
        events: vec![FaultEvent {
            t_s,
            group: Some(group),
            kind: FaultKind::Crash,
        }],
    }
}

/// THE acceptance run: the full kvp_convoy trace (4 KVP groups, three
/// 512K documents sharded 2-way, interactive traffic throughout) with one
/// group crashed while document shards are resident. The crash instant
/// and victim come from a fault-free probe run — just after a mid-run
/// onboard event, aimed at the group that onboarded — so the test tracks
/// the perf model instead of hard-coding timings.
#[test]
fn kvp_convoy_with_one_group_down_completes_every_request() {
    let cfg = workload::KvpConvoyConfig::default();
    let mut probe = run_kvp_convoy_scenario_with_faults(
        SchedPolicyKind::Lars,
        RoutingMode::Routed,
        &cfg,
        42,
        FaultPlan::default(),
    );
    let n_requests = probe.metrics.finished_requests;
    let clean_total = probe.metrics.prefill_tokens + probe.metrics.decode_tokens;
    let log = probe.kvp_onboard_log();
    assert!(!log.is_empty(), "probe run never sharded a document");
    let (t_mid, _, victim) = log[log.len() / 2];
    let crash_t = t_mid + 0.25;
    assert_eq!(probe.metrics.summary().finished, n_requests);

    let mut sim = run_kvp_convoy_scenario_with_faults(
        SchedPolicyKind::Lars,
        RoutingMode::Routed,
        &cfg,
        42,
        crash_plan(crash_t, victim),
    );

    // no request left behind: the degraded fleet finishes the same trace
    assert_eq!(sim.metrics.finished_requests, n_requests);
    for r in sim.retired() {
        assert!(r.is_finished(), "request {} unfinished after the crash", r.id);
        assert_eq!(r.prefilled, r.prompt_len, "prefill drift on request {}", r.id);
    }

    // degradation is visible, not fatal
    assert_eq!(sim.metrics.group_crashes, 1);
    assert!(sim.metrics.shards_lost > 0, "crash instant missed resident shards");
    assert!(sim.metrics.reprefill_tokens > 0);
    assert_eq!(sim.group_state(victim), GroupState::Down);
    assert_eq!(sim.n_active_groups(), 3);

    // boundary re-prefill, not full restart: the recomputed work is the
    // surplus over the fault-free run (a victim rewound across its prefill
    // boundary regenerates the first output token via the final prefill
    // chunk, unseen by either counter — at most one token per victim), and
    // strictly less than restarting the documents from scratch
    let total = sim.metrics.prefill_tokens + sim.metrics.decode_tokens;
    assert!(total >= clean_total, "the crash erased processed work");
    let surplus = total - clean_total;
    let s = sim.metrics.summary();
    assert!(
        surplus <= sim.metrics.reprefill_tokens
            && sim.metrics.reprefill_tokens <= surplus + s.n_recovered,
        "recomputed {} tokens for {} victims but re-processed {surplus}",
        sim.metrics.reprefill_tokens,
        s.n_recovered
    );
    assert!(
        sim.metrics.reprefill_tokens < cfg.doc_prompt * cfg.n_docs as u64,
        "re-prefill re-did more than the lost ranges"
    );

    // the logs witness the recovery: drops happen at the crash instant or
    // later, every drop names the dead group or a post-hole survivor, and
    // any re-onboarded (request, group) pair follows a drop of that pair
    // (the drop-aware exactly-once check)
    let drops = sim.kvp_drop_log();
    assert!(!drops.is_empty(), "crash dropped no shards");
    assert!(drops.iter().any(|&(_, _, g)| g == victim));
    for &(td, _, _) in drops {
        assert!(td >= crash_t, "a shard was dropped before the crash");
    }
    assert!(
        sim.kvp_onboard_log_is_duplicate_free(),
        "recovery re-onboarded a retained shard"
    );
    assert!(sim.kvp_ledger_is_conserved(), "ledger out of balance after recovery");

    // recovery wait was measured for the victims
    let s = sim.metrics.summary();
    assert!(s.n_recovered > 0);
    assert!(s.recovery_wait_p50 >= 0.0);
    assert!(s.recovery_wait_p95 >= s.recovery_wait_p50);
}

/// Graceful-degradation comparison the `faults` figure prints: with the
/// crash, goodput may drop and tails stretch, but the finished count must
/// not — for FCFS as well as LARS.
#[test]
fn degradation_is_graceful_for_both_policies() {
    let cfg = workload::KvpConvoyConfig {
        horizon_s: 15.0,
        doc_prompt: 128_000,
        n_docs: 2,
        doc_stagger_s: 6.0,
        ..workload::KvpConvoyConfig::default()
    };
    for (kind, routing) in [
        (SchedPolicyKind::Fcfs, RoutingMode::RoundRobin),
        (SchedPolicyKind::Lars, RoutingMode::Routed),
    ] {
        let clean =
            run_kvp_convoy_scenario_with_faults(kind, routing, &cfg, 7, FaultPlan::default());
        let mut crashed = run_kvp_convoy_scenario_with_faults(
            kind,
            routing,
            &cfg,
            7,
            crash_plan(5.0, 1),
        );
        let label = format!("{}/{}", kind.name(), routing.name());
        assert_eq!(
            crashed.metrics.finished_requests, clean.metrics.finished_requests,
            "{label}: the crash dropped requests"
        );
        assert_eq!(crashed.metrics.group_crashes, 1, "{label}");
        assert!(crashed.kvp_ledger_is_conserved(), "{label}");
        assert!(crashed.kvp_onboard_log_is_duplicate_free(), "{label}");
        // re-prefill work only ever adds to the fault-free totals (modulo
        // the one free first-output token per boundary-crossing victim)
        let clean_total = clean.metrics.prefill_tokens + clean.metrics.decode_tokens;
        let total = crashed.metrics.prefill_tokens + crashed.metrics.decode_tokens;
        assert!(total >= clean_total, "{label}: the crash erased processed work");
        let surplus = total - clean_total;
        let n_victims = crashed.metrics.summary().n_recovered;
        assert!(
            surplus <= crashed.metrics.reprefill_tokens
                && crashed.metrics.reprefill_tokens <= surplus + n_victims,
            "{label}: token conservation broke"
        );
    }
}

/// Fault-matrix smoke (CI: `MEDHA_BENCH_SMOKE=1`): generator-driven
/// crash→rejoin storms across every policy on both pooled routing modes,
/// on a trace heavy enough that outages overlap live document prefills.
/// Every request must finish through repeated fleet churn, with the
/// ledger balanced and the onboard log duplicate-free at the drain.
#[test]
fn fault_storm_matrix_smoke() {
    if std::env::var("MEDHA_BENCH_SMOKE").is_err() {
        return; // heavyweight: exercised by the CI fault-matrix job
    }
    let cfg = workload::KvpConvoyConfig {
        horizon_s: 20.0,
        doc_prompt: 256_000,
        n_docs: 2,
        doc_stagger_s: 8.0,
        ..workload::KvpConvoyConfig::default()
    };
    let n_requests = workload::kvp_convoy(&cfg, 7).len() as u64;
    let storm = fault_storm(
        &FaultStormConfig {
            n_groups: 4,
            n_cycles: 2,
            start_s: 3.0,
            window_s: 15.0,
            mean_gap_s: 3.0,
            mean_outage_s: 4.0,
            warmup_s: 0.5,
        },
        7,
    );
    assert!(!storm.is_empty(), "storm generator produced no events");
    for kind in SchedPolicyKind::ALL {
        for routing in [RoutingMode::RoundRobin, RoutingMode::Routed] {
            let sim =
                run_kvp_convoy_scenario_with_faults(kind, routing, &cfg, 7, storm.clone());
            let label = format!("{}/{}", kind.name(), routing.name());
            assert_eq!(
                sim.metrics.finished_requests, n_requests,
                "{label}: the storm left requests behind"
            );
            assert!(sim.kvp_ledger_is_conserved(), "{label}: ledger out of balance");
            assert!(
                sim.kvp_onboard_log_is_duplicate_free(),
                "{label}: a retained shard was re-onboarded"
            );
        }
    }
}
