//! Policy-aware KVP routing + active-long-request preemption, end to end:
//! routed LARS must keep short-request tails far below blind round-robin
//! placement on the `kvp_convoy` trace (the section 7 serving-pool
//! opportunity), documents must never starve, preemption counters must
//! distinguish queued re-orderings from active chunk-boundary yields, and
//! a preempted sharded prefill must resume **bit-exactly** — identical
//! final metrics to an uninterrupted run shifted by the yield window.

use medha::config::DeploymentConfig;
use medha::coordinator::{RoutingMode, SchedPolicyKind};
use medha::metrics::PreemptionKind;
use medha::sim::{kvp_convoy_ttft_split, run_kvp_convoy_scenario, SimOptions, Simulation};
use medha::workload::{KvpConvoyConfig, RequestSpec};

fn cfg() -> KvpConvoyConfig {
    KvpConvoyConfig::default()
}

#[test]
fn routed_lars_beats_blind_round_robin_on_short_p99_ttft() {
    let c = cfg();
    let rr = run_kvp_convoy_scenario(SchedPolicyKind::Lars, RoutingMode::RoundRobin, &c, 42);
    let routed = run_kvp_convoy_scenario(SchedPolicyKind::Lars, RoutingMode::Routed, &c, 42);
    // both placements drain the whole trace
    assert_eq!(rr.metrics.finished_requests, routed.metrics.finished_requests);
    assert!(rr.metrics.finished_requests > 100);
    let (mut rr_short, _) = kvp_convoy_ttft_split(&rr, &c);
    let (mut routed_short, routed_docs) = kvp_convoy_ttft_split(&routed, &c);
    assert!(!routed_docs.is_empty(), "trace must contain documents");
    let (rr_p99, routed_p99) = (rr_short.p99(), routed_short.p99());
    // the headline: blind round-robin keeps landing shorts on the groups
    // sharding the active document, where they wait out chunk-scale
    // cooperative iterations; routed placement steers them to the idle
    // serving pool
    assert!(
        rr_p99 >= 5.0 * routed_p99,
        "routing won only {rr_p99:.3}s vs {routed_p99:.3}s (need >= 5x)"
    );
}

#[test]
fn routed_lars_never_starves_documents() {
    let c = cfg();
    let sim = run_kvp_convoy_scenario(SchedPolicyKind::Lars, RoutingMode::Routed, &c, 42);
    let docs: Vec<&medha::coordinator::Request> = sim
        .retired()
        .iter()
        .filter(|r| c.is_doc(r.prompt_len))
        .collect();
    assert_eq!(docs.len(), c.n_docs);
    for d in docs {
        // starvation freedom: even while yielding to fresher documents and
        // ceding groups to short traffic, every document still makes its
        // own length-aware deadline (LARS headroom already inside it)
        let ttft = d.ttft().unwrap();
        assert!(
            ttft <= d.ttft_budget_s(),
            "document {} starved: ttft {ttft:.1}s > budget {:.1}s",
            d.id,
            d.ttft_budget_s()
        );
        assert!(d.is_finished());
    }
}

#[test]
fn preemption_counters_distinguish_queued_reorders_from_active_yields() {
    let c = cfg();
    let mut routed = run_kvp_convoy_scenario(SchedPolicyKind::Lars, RoutingMode::Routed, &c, 42);
    let s = routed.metrics.summary();
    // overlapping documents force at least one active chunk-boundary yield
    // (a fresh document's slack undercuts an ahead-of-schedule one)
    assert!(s.active_preemptions >= 1, "no active yields on overlapping documents");
    assert_eq!(
        s.active_preemptions,
        routed.metrics.preemption_events.len() as u64
    );
    assert!(routed
        .metrics
        .preemption_events
        .iter()
        .all(|e| e.kind == PreemptionKind::ActiveYield));
    // every yield names a document, never an interactive request
    assert!(routed
        .metrics
        .preemption_events
        .iter()
        .all(|e| c.is_doc(routed.request(e.request).unwrap().prompt_len)));
    // FCFS holds the active request to completion in every routing mode
    let fcfs = run_kvp_convoy_scenario(SchedPolicyKind::Fcfs, RoutingMode::RoundRobin, &c, 42);
    assert_eq!(fcfs.metrics.active_preemptions, 0);
    assert!(fcfs.metrics.preemption_events.is_empty());
}

/// Capacity-aware routed admission (PR 4): with a finite per-group KV
/// capacity, the routing hook refuses placements that would not fit, the
/// refused admissions are counted and deferred — and still nothing is
/// left behind once capacity frees. Blind placement on the same trace
/// never consults capacity.
#[test]
fn capacity_refusals_defer_admissions_without_losing_requests() {
    let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 2);
    dep.scheduler.policy = SchedPolicyKind::Lars;
    dep.scheduler.routing = RoutingMode::Routed;
    dep.scheduler.adaptive_chunking = false;
    dep.scheduler.static_chunk = 2048;
    // room for exactly two shorts per group at a time (each needs
    // prompt 512 + 8 output tokens of KV)
    dep.scheduler.kvp_capacity_tokens = 2 * (512 + 8);
    let w: Vec<RequestSpec> = (0..16)
        .map(|i| RequestSpec {
            id: i,
            prompt_len: 512,
            max_new_tokens: 8,
            arrival_s: 0.01 * i as f64,
            ..RequestSpec::default()
        })
        .collect();
    let mut sim = Simulation::new(dep.clone(), w.clone(), SimOptions::default());
    sim.run();
    assert_eq!(sim.metrics.finished_requests, 16, "deferred admissions were lost");
    assert!(
        sim.metrics.routing_refusals > 0,
        "a 16-deep burst against 4 concurrent slots must refuse placements"
    );
    assert_eq!(sim.n_live(), 0, "deferred requests leaked arena slots");
    // every request still produced its tokens exactly once
    for r in sim.retired() {
        assert_eq!(r.prefilled, r.prompt_len);
        assert_eq!(r.decoded, r.max_new_tokens);
    }
    // the same trace under blind placement ignores capacity entirely
    dep.scheduler.routing = RoutingMode::Blind;
    let mut blind = Simulation::new(dep, w, SimOptions::default());
    blind.run();
    assert_eq!(blind.metrics.routing_refusals, 0);
    assert_eq!(blind.metrics.finished_requests, 16);
}

/// A request bigger than a whole group's capacity can never satisfy the
/// capacity check: it is counted as a refusal but placed anyway (capacity
/// waived) rather than deferred forever.
#[test]
fn oversized_request_is_overflow_placed_not_deferred_forever() {
    let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 2);
    dep.scheduler.policy = SchedPolicyKind::Srpt;
    dep.scheduler.routing = RoutingMode::Routed;
    dep.scheduler.adaptive_chunking = false;
    dep.scheduler.static_chunk = 2048;
    dep.scheduler.kvp_capacity_tokens = 1_000; // smaller than the request
    let w = vec![RequestSpec {
        id: 0,
        prompt_len: 8_000, // short-path (below long_threshold), yet > capacity
        max_new_tokens: 4,
        ..RequestSpec::default()
    }];
    let mut sim = Simulation::new(dep, w, SimOptions::default());
    sim.run();
    assert_eq!(sim.metrics.finished_requests, 1, "oversized request starved");
    assert_eq!(sim.metrics.routing_refusals, 1);
    assert_eq!(sim.n_live(), 0);
}

/// Deferred-queue urgency ordering (the PR 5 inversion fix): capacity
/// deferral is no longer strict FIFO under preemptive policies. Two
/// giant blockers fill the only group's KV capacity; a slack-rich big
/// request defers first, a deadline-critical tiny one defers later. When
/// the first blocker retires, only the tiny request fits — under LARS it
/// must be admitted *then* (before the second blocker finishes), not
/// stuck behind the slack-rich head the old FIFO rule would have blocked
/// on.
fn deferral_trace() -> (DeploymentConfig, Vec<RequestSpec>, SimOptions) {
    let mut dep = DeploymentConfig::llama3_8b_tp8(); // kvp = 1: one group
    dep.scheduler.routing = RoutingMode::Routed;
    dep.scheduler.adaptive_chunking = false;
    dep.scheduler.static_chunk = 2048;
    // exactly the two blockers' combined KV footprint
    dep.scheduler.kvp_capacity_tokens = (2_000_000 + 2) + (2_500_000 + 2);
    let w = vec![
        // blockers: together they pin capacity at zero until one retires
        RequestSpec { id: 0, prompt_len: 2_000_000, max_new_tokens: 2, ..RequestSpec::default() },
        RequestSpec { id: 1, prompt_len: 2_500_000, max_new_tokens: 2, ..RequestSpec::default() },
        // slack-rich big request: defers first, and fits only once BOTH
        // blockers are gone (its need exceeds either blocker's own
        // footprint, so a single retirement can never free enough)
        RequestSpec { id: 2, prompt_len: 2_600_000, max_new_tokens: 4, arrival_s: 0.1, ..RequestSpec::default() },
        // deadline-critical tiny request: defers later, fits as soon as
        // the first blocker frees; its floor deadline is long blown by
        // then (multi-million-token prefills take far more than 2 s)
        RequestSpec { id: 3, prompt_len: 256, max_new_tokens: 4, arrival_s: 0.3, ..RequestSpec::default() },
    ];
    // everything through the group scheduler: capacity is the only gate
    let opts = SimOptions { long_threshold: u64::MAX, ..SimOptions::default() };
    (dep, w, opts)
}

#[test]
fn deferred_queue_orders_retries_by_urgency_under_lars() {
    let (mut dep, w, opts) = deferral_trace();
    dep.scheduler.policy = SchedPolicyKind::Lars;
    let mut sim = Simulation::new(dep, w, opts);
    sim.run();
    assert_eq!(sim.metrics.finished_requests, 4);
    // both the big and the tiny request were refused exactly once each
    assert_eq!(sim.metrics.routing_refusals, 2);
    let s = sim.metrics.summary();
    assert_eq!(s.n_deferred, 2, "both deferrals must be placed and timed");
    assert!(s.deferral_wait_p95 > 0.0);
    let blockers_done = sim
        .request(0)
        .unwrap()
        .finished_s
        .unwrap()
        .max(sim.request(1).unwrap().finished_s.unwrap());
    let small = sim.request(3).unwrap();
    let big = sim.request(2).unwrap();
    // the inversion fix: the later-arriving deadline-critical request is
    // admitted at the first capacity release — no later than the last
    // blocker's retirement — and served immediately...
    assert!(
        small.first_token_s.unwrap() <= blockers_done,
        "deadline-critical short waited out the slack-rich head: \
         first_token {} > last blocker finish {blockers_done}",
        small.first_token_s.unwrap()
    );
    // ...while the slack-rich one keeps waiting for its capacity (it can
    // only fit once both blockers are gone) and serves strictly after
    assert!(
        big.first_token_s.unwrap() > blockers_done,
        "the big request cannot fit before both blockers retire"
    );
    assert!(
        small.first_token_s.unwrap() < big.first_token_s.unwrap(),
        "urgency-ordered deferral must serve the deadline-critical short first"
    );
}

#[test]
fn deferred_queue_stays_fifo_under_fcfs() {
    let (mut dep, w, opts) = deferral_trace();
    dep.scheduler.policy = SchedPolicyKind::Fcfs;
    let mut sim = Simulation::new(dep, w, opts);
    sim.run();
    assert_eq!(sim.metrics.finished_requests, 4);
    let blockers_done = sim
        .request(0)
        .unwrap()
        .finished_s
        .unwrap()
        .max(sim.request(1).unwrap().finished_s.unwrap());
    let small = sim.request(3).unwrap();
    let big = sim.request(2).unwrap();
    // FIFO retained: the tiny request queues behind the big head (which
    // does not fit until both blockers retire), exactly the old strict
    // head-blocking behavior — and then serves after the head's prefill
    assert!(
        small.first_token_s.unwrap() > blockers_done,
        "FCFS deferral must keep strict FIFO head-blocking"
    );
    assert!(
        small.first_token_s.unwrap() > big.first_token_s.unwrap(),
        "FCFS serves the FIFO head first"
    );
    assert_eq!(sim.metrics.summary().n_deferred, 2);
}

/// The KV-integrity contract: preempt the active sharded document
/// mid-prefill, run the preempting work to completion on other groups,
/// resume — and the interrupted run's final metrics equal the
/// uninterrupted run's, shifted by exactly the yield window.
#[test]
fn preempted_prefill_resumes_bit_exactly_shifted_by_the_yield_window() {
    let build = |with_challenger: bool| {
        let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 4);
        dep.scheduler.policy = SchedPolicyKind::Srpt;
        dep.scheduler.routing = RoutingMode::Routed;
        dep.scheduler.adaptive_chunking = false;
        dep.scheduler.static_chunk = 2048;
        dep.scheduler.kvp_onboard_threshold = 64_000;
        let mut w = vec![RequestSpec {
            id: 0,
            prompt_len: 200_000,
            max_new_tokens: 6,
            ..RequestSpec::default()
        }];
        if with_challenger {
            // strictly less remaining work under SRPT: preempts doc 0 at
            // the first chunk boundary past its arrival
            w.push(RequestSpec {
                id: 1,
                prompt_len: 32_000,
                max_new_tokens: 4,
                arrival_s: 1.0,
                ..RequestSpec::default()
            });
        }
        let mut sim = Simulation::new(dep, w, SimOptions::default());
        sim.run();
        sim
    };
    let solo = build(false);
    let both = build(true);
    let a_solo = solo.request(0).unwrap();
    let a = both.request(0).unwrap();
    let b = both.request(1).unwrap();

    // token-exact resume: nothing lost, nothing recomputed
    assert_eq!(a.prefilled, 200_000);
    assert_eq!(a.decoded, 6);
    assert_eq!(both.metrics.prefill_tokens, 232_000);
    assert_eq!(both.metrics.active_preemptions, 1);
    assert_eq!(both.metrics.preemption_events[0].request, 0);

    // identical decode cadence: the preempted document's TBT samples match
    // the uninterrupted run's one-for-one
    assert_eq!(a.tbt_samples.len(), a_solo.tbt_samples.len());
    for (x, y) in a.tbt_samples.iter().zip(&a_solo.tbt_samples) {
        assert!((x - y).abs() < 1e-9, "tbt drifted: {x} vs {y}");
    }

    // the TTFT shift is exactly the yield window: chunk-boundary yield to
    // the instant the preempting document released the cooperative slot
    let yield_t = both.metrics.preemption_events[0].t;
    let window = b.finished_s.unwrap() - yield_t;
    assert!(window > 0.0);
    let shift = a.ttft().unwrap() - a_solo.ttft().unwrap();
    assert!(
        (shift - window).abs() < 1e-6,
        "ttft shift {shift:.6}s != yield window {window:.6}s"
    );

    // the retained shards were never re-onboarded across the yield
    assert!(
        both.kvp_onboard_log_is_duplicate_free(),
        "a retained shard was re-onboarded"
    );
}
