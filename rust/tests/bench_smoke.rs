//! Bench-path smoke test: runs the simulator throughput benches once in
//! smoke mode (env-var capped iterations, down-scaled workloads) and
//! validates the `BENCH_sim.json` document they emit — so `cargo test`
//! keeps the bench machinery compiling and its output parseable without
//! paying full bench budgets.

use medha::sim::throughput::{
    decode_stream_workload, mixed_million_workload, run_sim_throughput, throughput_dep,
};
use medha::util::bench::{BenchSuite, MAX_ITERS_ENV, SMOKE_ENV};
use medha::util::json::Json;

#[test]
fn smoke_run_emits_valid_bench_json() {
    std::env::set_var(SMOKE_ENV, "1");
    let mut suite = BenchSuite::with_budget(5.0, None);
    assert!(suite.is_smoke());

    let mut calls = 0u64;
    suite.bench("smoke/counter", || {
        calls += 1;
    });
    // smoke mode caps timed iterations at 2 (plus <=3 warmup calls)
    assert!(calls <= 5, "smoke mode ran {calls} calls");

    // one pass of each sim throughput bench, down-scaled
    let reports = vec![
        run_sim_throughput(
            "sim/throughput decode-stream",
            throughput_dep(1),
            decode_stream_workload(8, 300),
        ),
        run_sim_throughput(
            "sim/million mixed",
            throughput_dep(2),
            mixed_million_workload(1_000, 2, 7),
        ),
    ];
    for r in &reports {
        assert!(r.finished > 0, "{}: nothing finished", r.name);
        assert!(r.iterations > 0 && r.wall_s > 0.0);
    }

    // one concurrent-sweep pass, down-scaled even further than smoke():
    // the full 12-cell policy x routing matrix across 4 worker threads,
    // feeding the `sweep` section the benches record
    let sweep_cfg = medha::sim::sweep::SweepConfig {
        threads: 4,
        load_levels: vec![1.0],
        trace: medha::workload::KvpConvoyConfig {
            rate_per_s: 4.0,
            horizon_s: 2.5,
            doc_prompt: 48_000,
            n_docs: 1,
            doc_start_s: 0.5,
            doc_stagger_s: 1.0,
            ..medha::workload::KvpConvoyConfig::default()
        },
        ..medha::sim::sweep::SweepConfig::default()
    };
    let (outcomes, _wall) = medha::sim::sweep::run_sweep(&sweep_cfg);
    assert_eq!(outcomes.len(), 12);
    assert!(outcomes.iter().any(|o| o.on_frontier), "empty Pareto frontier");

    let dir = std::env::temp_dir().join("medha_bench_smoke");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_sim.json");
    suite
        .write_json(
            &path,
            vec![
                (
                    "sim_throughput",
                    Json::arr(reports.iter().map(|r| r.to_json())),
                ),
                ("sweep", Json::arr(outcomes.iter().map(|o| o.to_json()))),
            ],
        )
        .unwrap();

    // the emitted document must round-trip through our own parser
    let text = std::fs::read_to_string(&path).unwrap();
    let j = Json::parse(&text).unwrap();
    assert_eq!(j.get("smoke").and_then(|x| x.as_bool()), Some(true));
    let results = j.get("results").unwrap().as_arr().unwrap();
    assert!(!results.is_empty());
    let sims = j.get("sim_throughput").unwrap().as_arr().unwrap();
    assert_eq!(sims.len(), 2);
    for s in sims {
        assert!(s.get("iters_per_s").and_then(|x| x.as_f64()).unwrap() > 0.0);
        assert!(s.get("name").and_then(|x| x.as_str()).is_some());
    }
    let sweep = j.get("sweep").unwrap().as_arr().unwrap();
    assert_eq!(sweep.len(), 12);
    for c in sweep {
        assert!(c.get("policy").and_then(|x| x.as_str()).is_some());
        assert!(c.get("routing").and_then(|x| x.as_str()).is_some());
        assert!(c.get("on_frontier").and_then(|x| x.as_bool()).is_some());
    }

    std::env::remove_var(SMOKE_ENV);
    std::env::remove_var(MAX_ITERS_ENV);
}
