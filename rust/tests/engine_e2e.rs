//! End-to-end engine tests against real artifacts: golden-generation match
//! (Rust+PJRT == pure-JAX reference), chunking invariance, and KVP
//! shard/merge equivalence — the core "all layers compose" proof.

use std::path::PathBuf;

use medha::engine::pipeline::{serve, ServeRequest};
use medha::engine::{detokenize, tokenize, Engine};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine(lps: u32) -> Option<Engine> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (`make artifacts`)");
        return None;
    }
    Some(Engine::load(artifacts_dir(), lps).unwrap())
}

#[test]
fn golden_generation_matches_jax_reference() {
    let Some(e) = engine(8) else { return };
    let n = e.verify_golden().unwrap();
    assert!(n >= 8);
}

#[test]
fn chunking_invariance_on_real_engine() {
    // Same prompt prefilled with different chunk caps must produce the same
    // next token — adaptive chunking's correctness precondition, verified
    // on the real runtime.
    let Some(e) = engine(8) else { return };
    let prompt = tokenize("The quadratic cost of attention grows fast.");
    let a = e.generate(&prompt, 4, 256).unwrap();
    let b = e.generate(&prompt, 4, 16).unwrap();
    assert_eq!(a, b, "chunk cap changed the output");
}

#[test]
fn staged_execution_matches_monolithic() {
    // 4 stages of 2 layers == 1 stage of 8 layers (SPP correctness).
    let Some(e1) = engine(8) else { return };
    let Some(e4) = engine(2) else { return };
    let prompt = tokenize("pipeline stages compose");
    let a = e1.generate(&prompt, 6, 64).unwrap();
    let b = e4.generate(&prompt, 6, 64).unwrap();
    assert_eq!(a, b, "stage split changed the output");
}

#[test]
fn generated_text_is_deterministic() {
    let Some(e) = engine(8) else { return };
    let prompt = tokenize("abc");
    let a = e.generate(&prompt, 8, 64).unwrap();
    let b = e.generate(&prompt, 8, 64).unwrap();
    assert_eq!(a, b);
    // tokens are bytes; detokenize must not panic
    let _ = detokenize(&a);
}

#[test]
fn pipeline_serve_matches_direct_engine() {
    // The multi-threaded SPP pipeline (2 stages, separate PJRT clients)
    // must produce exactly the same tokens as the single-client engine.
    let Some(e) = engine(4) else { return };
    let prompt = tokenize("pipeline equals direct execution");
    let direct = e.generate(&prompt, 6, 16).unwrap();
    let rep = serve(
        artifacts_dir(),
        2,
        16,
        &[ServeRequest {
            prompt: prompt.clone(),
            max_new_tokens: 6,
        }],
    )
    .unwrap();
    assert_eq!(rep.requests[0].generated, direct);
    assert!(rep.requests[0].ttft_s > 0.0);
    assert_eq!(rep.decode_tokens, 6);
}

#[test]
fn kvp_sharded_equals_monolithic_attention() {
    let Some(e) = engine(8) else { return };
    let spec = e.spec;
    let row = spec.hkv * spec.d_head;
    let n = 1024usize;
    let kv_len = 900usize;
    // deterministic pseudo-random q/k/v
    let gen = |seed: u64, len: usize| -> Vec<f32> {
        let mut rng = medha::util::rng::Rng::new(seed);
        (0..len).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect()
    };
    let q = gen(1, spec.hq * spec.d_head);
    let k = gen(2, n * row);
    let v = gen(3, n * row);

    let mono = e
        .monolithic_decode_attention(&q, &k, &v, kv_len, 1024)
        .unwrap();
    let sharded = e.kvp_decode_attention(&q, &k, &v, kv_len, 512, 2).unwrap();
    assert_eq!(mono.len(), sharded.len());
    for (a, b) in mono.iter().zip(&sharded) {
        assert!((a - b).abs() < 2e-5, "kvp mismatch: {a} vs {b}");
    }
}

#[test]
fn kvp_with_empty_tail_shard() {
    // kv_len entirely inside shard 0: shard 1 is dead, merge must still be
    // exact (dynamic onboarding's freshly-added empty workers).
    let Some(e) = engine(8) else { return };
    let spec = e.spec;
    let row = spec.hkv * spec.d_head;
    let gen = |seed: u64, len: usize| -> Vec<f32> {
        let mut rng = medha::util::rng::Rng::new(seed);
        (0..len).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect()
    };
    let q = gen(4, spec.hq * spec.d_head);
    let k = gen(5, 1024 * row);
    let v = gen(6, 1024 * row);
    let kv_len = 300; // < 512: shard 1 has zero valid rows
    let mono = e
        .monolithic_decode_attention(&q, &k, &v, kv_len, 512)
        .unwrap();
    let sharded = e.kvp_decode_attention(&q, &k, &v, kv_len, 512, 2).unwrap();
    for (a, b) in mono.iter().zip(&sharded) {
        assert!((a - b).abs() < 2e-5, "{a} vs {b}");
    }
}
