//! Acceptance suite for the open-loop online serving mode (`sim::serve` +
//! `coordinator::admission`): the overload contract from the paper's
//! no-request-left-behind stance, restated for the open loop — when offered
//! load exceeds capacity, the system degrades *gracefully*: admitted
//! requests keep their SLOs (goodput plateaus at the paced rate instead of
//! collapsing), the excess is shed or rejected at the door with per-class
//! accounting, bounded queues never overflow their limits, and with the
//! gate wide open the whole driver is bit-identical to the closed loop.

use medha::coordinator::{AdmissionConfig, BucketConfig, RoutingMode, SchedPolicyKind};
use medha::sim::serve::{run_serve_scenario, serve_scenario_dep, ServeSim};
use medha::sim::{SimOptions, Simulation};
use medha::workload::openloop::{generate, OpenLoopConfig, Scenario};

/// Shared open-loop shape: small enough for test wall-clock, hot enough
/// (6 req/s with a document every 24th arrival) for real contention.
fn base_cfg() -> OpenLoopConfig {
    OpenLoopConfig {
        base_rate_per_s: 6.0,
        horizon_s: 12.0,
        doc_prompt: 65_536,
        doc_every: 24,
        ..OpenLoopConfig::default()
    }
}

/// A gate paced clearly below fleet capacity: with the buckets binding,
/// the admitted stream is rate-limited to ~3 short/s + ~0.1 doc/s no
/// matter how much is offered — the mechanism behind the goodput plateau.
fn paced_gate(cfg: &OpenLoopConfig) -> AdmissionConfig {
    AdmissionConfig {
        short: BucketConfig {
            rate_per_s: 3.0,
            burst: 6.0,
            queue_limit: 64,
        },
        doc: BucketConfig {
            rate_per_s: 0.1,
            burst: 1.0,
            queue_limit: 4,
        },
        doc_threshold: cfg.doc_prompt,
        shed_deferral_frac: 0.0,
        ..AdmissionConfig::default()
    }
}

/// Bit-exact outcome signature: summary statistics as raw f64 bits plus
/// per-request `(id, ttft)` pairs over the retired set.
fn outcome_sig(sim: &mut Simulation, end: f64) -> Vec<u64> {
    let s = sim.metrics.summary();
    let mut v = vec![
        end.to_bits(),
        s.finished,
        s.goodput_rps.to_bits(),
        s.ttft_p50.to_bits(),
        s.ttft_p95.to_bits(),
        s.tbt_p50.to_bits(),
        s.tbt_p95.to_bits(),
        s.tbt_p99.to_bits(),
        s.tbt_max.to_bits(),
        s.ttft_attainment.to_bits(),
        s.tbt_attainment.to_bits(),
        s.deferral_wait_p95.to_bits(),
        s.routing_refusals,
        s.n_deferred,
        s.preemptions,
        s.active_preemptions,
    ];
    for r in sim.retired() {
        v.push(r.id);
        v.push(r.ttft().map_or(u64::MAX, f64::to_bits));
    }
    v
}

/// With the pass-through gate (unpaced buckets, unbounded queues, shedding
/// off) every open-loop scenario must replay bit-identically to feeding
/// the same trace straight into the closed-loop core — the equivalence
/// contract that keeps serve-sim from forking the simulator's semantics.
#[test]
fn pass_through_open_loop_matches_closed_loop_on_every_scenario() {
    let cfg = base_cfg();
    for scenario in [Scenario::Flash, Scenario::Diurnal, Scenario::Overcommit] {
        let source = generate(scenario, &cfg, 42);
        let dep = serve_scenario_dep(SchedPolicyKind::Lars, RoutingMode::Routed, &cfg);

        let mut closed = Simulation::new(dep.clone(), source.clone(), SimOptions::default());
        let end_closed = closed.run();

        let mut open = ServeSim::new(dep, source, SimOptions::default(), AdmissionConfig::default());
        let end_open = open.run();

        assert_eq!(
            outcome_sig(&mut closed, end_closed),
            outcome_sig(&mut open.sim, end_open),
            "{}: pass-through open loop diverged from the closed loop",
            scenario.name()
        );
        let s = open.sim.metrics.summary();
        assert_eq!(s.n_shed, 0, "{}: pass-through shed", scenario.name());
        assert_eq!(s.n_rejected_queue_full, 0, "{}: pass-through rejected", scenario.name());
    }
}

/// The tentpole claim: with admission paced below capacity, doubling the
/// offered load does not move goodput — the gate admits the same paced
/// stream and the excess is dropped at the door. Goodput at 2x overcommit
/// must stay within 10% of the capacity-matched (1x) run, while the drop
/// counters grow with the offered excess.
#[test]
fn goodput_plateaus_when_offered_load_doubles() {
    let run = |mult: f64| -> (u64, medha::metrics::MetricsSummary) {
        let cfg = OpenLoopConfig {
            overcommit_mult: mult,
            ..base_cfg()
        };
        let gate = paced_gate(&cfg);
        let mut serve = run_serve_scenario(
            Scenario::Overcommit,
            &cfg,
            SchedPolicyKind::Lars,
            RoutingMode::Routed,
            gate,
            42,
        );
        let offered = serve.n_offered();
        (offered, serve.sim.metrics.summary())
    };
    let (offered_1x, s1) = run(1.0);
    let (offered_2x, s2) = run(2.0);
    assert!(
        offered_2x as f64 > 1.5 * offered_1x as f64,
        "degenerate sweep: {offered_2x} offered at 2x vs {offered_1x} at 1x"
    );
    assert!(s1.goodput_rps > 0.0, "capacity-matched run produced no goodput");
    let ratio = s2.goodput_rps / s1.goodput_rps;
    assert!(
        (0.9..=1.1).contains(&ratio),
        "goodput did not plateau: {:.3} req/s at 1x vs {:.3} req/s at 2x ({ratio:.2}x)",
        s1.goodput_rps,
        s2.goodput_rps
    );
    let dropped_1x = s1.n_shed + s1.n_rejected_queue_full;
    let dropped_2x = s2.n_shed + s2.n_rejected_queue_full;
    assert!(
        dropped_2x > dropped_1x,
        "doubling offered load must drop more at the door ({dropped_1x} -> {dropped_2x})"
    );
    assert!(dropped_2x > 0, "2x overcommit against a paced gate never dropped");
}

/// Per-class accounting and queue bounds under heavy overload: every drop
/// lands in exactly one class counter, and the bounded per-class queues
/// never exceed their configured limits (tracked via high-water marks).
#[test]
fn overload_drops_are_class_correct_and_queues_stay_bounded() {
    let cfg = OpenLoopConfig {
        overcommit_mult: 3.0,
        ..base_cfg()
    };
    let gate = paced_gate(&cfg);
    let (short_limit, doc_limit) = (gate.short.queue_limit, gate.doc.queue_limit);
    let mut serve = run_serve_scenario(
        Scenario::Overcommit,
        &cfg,
        SchedPolicyKind::Lars,
        RoutingMode::Routed,
        gate,
        42,
    );
    assert!(
        serve.admission().short_q_high_water <= short_limit,
        "short queue exceeded its limit: {} > {short_limit}",
        serve.admission().short_q_high_water
    );
    assert!(
        serve.admission().doc_q_high_water <= doc_limit,
        "doc queue exceeded its limit: {} > {doc_limit}",
        serve.admission().doc_q_high_water
    );
    let offered = serve.n_offered();
    let s = serve.sim.metrics.summary();
    assert_eq!(s.n_shed, s.n_shed_short + s.n_shed_doc, "shed classes don't sum");
    assert_eq!(
        s.n_rejected_queue_full,
        s.n_rejected_short + s.n_rejected_doc,
        "reject classes don't sum"
    );
    assert!(
        s.n_rejected_queue_full > 0,
        "3x overcommit against bounded queues never overflowed"
    );
    assert!(
        s.finished + s.n_shed + s.n_rejected_queue_full <= offered,
        "conservation: {} finished + {} dropped > {} offered",
        s.finished,
        s.n_shed + s.n_rejected_queue_full,
        offered
    );
}

/// SLO-feedback shedding, exercised deterministically: pre-loading the
/// rolling deferral-wait distribution far past every TTFT budget makes
/// each short arrival project negative slack, so it is shed at the door —
/// and sheds are metered per class like every other drop.
#[test]
fn slo_feedback_shedding_fires_and_is_class_correct() {
    let cfg = base_cfg();
    let dep = serve_scenario_dep(SchedPolicyKind::Lars, RoutingMode::Routed, &cfg);
    let source = generate(Scenario::Overcommit, &cfg, 42);
    let gate = AdmissionConfig {
        shed_deferral_frac: 0.5,
        doc_threshold: cfg.doc_prompt,
        ..AdmissionConfig::default()
    };
    let mut serve = ServeSim::new(dep, source, SimOptions::default(), gate);
    for _ in 0..50 {
        serve.sim.metrics.record_deferral_wait(1_000.0);
    }
    serve.run();
    let s = serve.sim.metrics.summary();
    assert!(s.n_shed > 0, "crushing deferral pressure never shed an arrival");
    assert!(s.n_shed_short > 0, "short arrivals project late first");
    assert_eq!(s.n_shed, s.n_shed_short + s.n_shed_doc);
    assert_eq!(s.n_rejected_queue_full, 0, "unbounded queues must never reject");
}
