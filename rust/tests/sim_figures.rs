//! Integration tests asserting the *shapes* of the paper's key results on
//! the simulated substrate (the quantitative claims DESIGN.md §5 commits to).

use medha::baselines::{striped_prefill_time, RingConfig, VllmModel};
use medha::config::DeploymentConfig;
use medha::perfmodel::PerfModel;
use medha::sim::{SimOptions, Simulation};
use medha::workload;

fn dep8b(tp: u32, spp: u32, kvp: u32) -> DeploymentConfig {
    DeploymentConfig::llama3_8b_tp8().with_parallel(tp, spp, kvp)
}

fn pm(dep: &DeploymentConfig) -> PerfModel {
    PerfModel::new(dep.model.clone(), dep.hardware.clone(), dep.parallel)
}

#[test]
fn fig14a_medha_beats_striped_at_scale() {
    // Paper: Medha 2D ~64% faster than striped attention at 16 servers.
    let dep = dep8b(8, 16, 1);
    let p = pm(&dep);
    let cfg = RingConfig { p: 16, tp: 8 };
    let striped = striped_prefill_time(&dep.model, &dep.hardware, &cfg, 1_000_000);
    let medha = p.prefill_time_spp(1_000_000, 4096);
    let gain = striped / medha - 1.0;
    assert!((0.3..1.2).contains(&gain), "gain={gain} (paper: 0.64)");
    // and the gap must GROW with scale
    let dep2 = dep8b(8, 2, 1);
    let p2 = pm(&dep2);
    let cfg2 = RingConfig { p: 2, tp: 8 };
    let gain2 = striped_prefill_time(&dep2.model, &dep2.hardware, &cfg2, 1_000_000)
        / p2.prefill_time_spp(1_000_000, 4096)
        - 1.0;
    assert!(gain > gain2, "gap should grow with scale: {gain2} -> {gain}");
}

#[test]
fn fig15_spp_scaling_efficiency_above_80pct() {
    let t1 = pm(&dep8b(8, 1, 1)).prefill_time_spp(2_000_000, 4096);
    let t16 = pm(&dep8b(8, 16, 1)).prefill_time_spp(2_000_000, 4096);
    let eff = t1 / (16.0 * t16);
    assert!(eff > 0.8, "eff={eff}");
}

#[test]
fn fig15_ttft_slo_met_at_2m_with_16_servers() {
    // Paper: 30s TTFT met up to 2M for 8B with 16 DGX servers.
    let t = pm(&dep8b(8, 16, 1)).prefill_time_spp(2_000_000, 4096);
    assert!(t < 30.0, "TTFT {t}s");
}

#[test]
fn fig15_70b_memory_crosses() {
    // Red crosses: 70B 10M infeasible below spp=8.
    let m70 = DeploymentConfig::llama3_70b_tp8();
    assert!(!pm(&m70.clone().with_parallel(8, 4, 1)).fits_memory(10_000_000));
    assert!(pm(&m70.with_parallel(8, 8, 1)).fits_memory(10_000_000));
}

#[test]
fn fig16_spp_decode_penalty_marginal() {
    let t2 = pm(&dep8b(8, 2, 1)).decode_tbt(2_000_000);
    let t16 = pm(&dep8b(8, 16, 1)).decode_tbt(2_000_000);
    assert!(t16 / t2 < 2.0, "spp16/spp2 = {}", t16 / t2);
}

#[test]
fn fig17_kvp_gains_grow_with_context() {
    let s4m = pm(&dep8b(8, 4, 1)).decode_tbt(4_000_000) / pm(&dep8b(8, 4, 4)).decode_tbt(4_000_000);
    let s10m =
        pm(&dep8b(8, 4, 1)).decode_tbt(10_000_000) / pm(&dep8b(8, 4, 4)).decode_tbt(10_000_000);
    assert!(s4m > 1.3 && s10m > s4m, "s4m={s4m} s10m={s10m}");
    // sublinear (Amdahl): never the full 4x
    assert!(s10m < 4.0);
}

#[test]
fn fig13_vllm_gaps() {
    let dep = dep8b(8, 1, 1);
    let p = pm(&dep);
    let v = VllmModel::new(dep.model.clone(), dep.hardware.clone(), dep.parallel);
    // decode gap at 2M in the paper's ~3.8-4x range
    let gap = v.decode_tbt(2_000_000) / p.decode_tbt(2_000_000);
    assert!((2.0..8.0).contains(&gap), "decode gap {gap}");
    // small-chunk prefill gap ~6x
    let pgap = v.prefill_time_chunked(1_000_000, 128) / p.prefill_time_monolithic(1_000_000, 128);
    assert!((3.0..12.0).contains(&pgap), "prefill gap {pgap}");
}

#[test]
fn fig8_adaptive_dominates_static_extremes() {
    let run = |adaptive: bool, chunk: u64| {
        let mut dep = dep8b(8, 1, 1);
        dep.scheduler.adaptive_chunking = adaptive;
        dep.scheduler.static_chunk = chunk;
        let w = workload::long_plus_decodes(500_000, 8, 1_000, 1_000);
        let mut sim = Simulation::new(dep, w, SimOptions::default());
        sim.run();
        let ttft = sim.request(0).unwrap().ttft().unwrap();
        let p95 = sim.metrics.tbt.p95();
        (ttft, p95)
    };
    let (ttft_small, tbt_small) = run(false, 32); // good TBT, bad TTFT
    let (ttft_big, tbt_big) = run(false, 4096); // good TTFT, bad TBT
    let (ttft_ad, tbt_ad) = run(true, 0);
    // adaptive must get (near-)best-of-both: TTFT much closer to the big
    // chunk than the small chunk, TBT much closer to the small chunk.
    assert!(ttft_ad < ttft_small * 0.6, "ttft adaptive {ttft_ad} vs small {ttft_small}");
    assert!(tbt_ad < tbt_big * 0.6, "tbt adaptive {tbt_ad} vs big {tbt_big}");
    assert!(ttft_big < ttft_small && tbt_small < tbt_big, "sanity");
}

#[test]
fn fig19_gpu_staircase_with_stable_iterations() {
    let mut dep = dep8b(8, 4, 4);
    dep.scheduler.kvp_onboard_threshold = 500_000;
    let w = workload::single_long(2_000_000, 8);
    let mut sim = Simulation::new(dep, w, SimOptions::default());
    sim.run();
    let gpus: Vec<u32> = sim.metrics.iters.iter().map(|r| r.active_gpus).collect();
    // staircase 32 -> 128
    assert_eq!(gpus.first().copied().unwrap(), 32);
    assert_eq!(gpus.iter().copied().max().unwrap(), 128);
    for lvl in [32u32, 64, 96, 128] {
        assert!(gpus.contains(&lvl), "missing staircase level {lvl}");
    }
    // near-constant iteration time: growth vs context is bounded (the
    // opposing forces of Fig. 19) — compare last decile mean to first.
    let durs: Vec<f64> = sim
        .metrics
        .iters
        .iter()
        .filter(|r| r.chunk.is_some())
        .map(|r| r.dur_s)
        .collect();
    let k = durs.len() / 10;
    let head: f64 = durs[..k].iter().sum::<f64>() / k as f64;
    let tail: f64 = durs[durs.len() - k..].iter().sum::<f64>() / k as f64;
    assert!(
        tail / head < 3.0,
        "iteration time should stay near-constant: head {head} tail {tail}"
    );
}

#[test]
fn fig22_batching_decodes_is_nearly_free() {
    let p = pm(&dep8b(8, 1, 1));
    use medha::perfmodel::{BatchShape, DecodeWork, PrefillWork};
    let alone = p
        .iteration_time(&BatchShape::prefill_only(2048, 1_000_000))
        .total();
    let with_128 = p
        .iteration_time(&BatchShape {
            prefills: vec![PrefillWork { chunk: 2048, kv_len: 1_000_000 }],
            decodes: (0..128).map(|_| DecodeWork { kv_len: 1_000 }).collect(),
        })
        .total();
    assert!(with_128 / alone < 1.05, "batching inflation {}", with_128 / alone);
}

#[test]
fn sim_backed_figures_run() {
    // The sim-backed harnesses execute end-to-end (stdout only).
    for f in ["fig8", "fig19", "sched"] {
        medha::figures::run(f).unwrap_or_else(|e| panic!("{f}: {e}"));
    }
}
