//! Integration: load real artifacts, compile on PJRT CPU, execute entries,
//! and check output shapes/numerics plumbing end-to-end.

use std::path::PathBuf;

use medha::runtime::{lit_f32, lit_i32, lit_zeros_f32, load_weights, to_vec_f32, Runtime};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (`make artifacts`)");
        return None;
    }
    Some(Runtime::load(artifacts_dir()).unwrap())
}

#[test]
fn embed_and_lm_head_execute() {
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    let w = load_weights(&artifacts_dir(), m).unwrap();
    let emb = &w["embed"];
    let tokens: Vec<i32> = (0..16).collect();
    let out = rt
        .call(
            "embed_c16",
            &[
                lit_i32(&[16], &tokens).unwrap(),
                lit_f32(&emb.shape, &emb.data).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 1);
    let h = to_vec_f32(&out[0]).unwrap();
    assert_eq!(h.len(), 16 * m.spec.d_model);
    // embedding lookup: row i of output == row tokens[i] of the table
    for i in 0..16 {
        let want = &emb.data[(i as usize) * m.spec.d_model..(i as usize + 1) * m.spec.d_model];
        let got = &h[i * m.spec.d_model..(i + 1) * m.spec.d_model];
        assert_eq!(got, want);
    }

    let fnorm = &w["final_norm"];
    let logits = rt
        .call(
            "lm_head_c16",
            &[
                out[0].clone(),
                lit_f32(&fnorm.shape, &fnorm.data).unwrap(),
                lit_f32(&emb.shape, &emb.data).unwrap(),
            ],
        )
        .unwrap();
    let lv = to_vec_f32(&logits[0]).unwrap();
    assert_eq!(lv.len(), 16 * m.spec.vocab);
    assert!(lv.iter().all(|x| x.is_finite()));
}

#[test]
fn stage_forward_executes_and_updates_cache() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest.clone();
    let w = load_weights(&artifacts_dir(), &m).unwrap();
    let (lps, c) = (2usize, 16usize);
    let spec = m.spec;

    // h from embed
    let emb = &w["embed"];
    let tokens: Vec<i32> = (5..5 + c as i32).collect();
    let h = rt
        .call(
            "embed_c16",
            &[
                lit_i32(&[c], &tokens).unwrap(),
                lit_f32(&emb.shape, &emb.data).unwrap(),
            ],
        )
        .unwrap()
        .remove(0);

    let cache_shape = [lps, spec.max_seq, spec.hkv, spec.d_head];
    let mut args = vec![
        h,
        lit_zeros_f32(&cache_shape).unwrap(),
        lit_zeros_f32(&cache_shape).unwrap(),
        lit_i32(&[1], &[0]).unwrap(),
    ];
    for layer in 0..lps {
        for nm in &m.layer_weight_names {
            let t = &w[&format!("layers.{layer}.{nm}")];
            args.push(lit_f32(&t.shape, &t.data).unwrap());
        }
    }
    let out = rt.call("stage_c16_l2", &args).unwrap();
    assert_eq!(out.len(), 3);
    let h2 = to_vec_f32(&out[0]).unwrap();
    assert_eq!(h2.len(), c * spec.d_model);
    assert!(h2.iter().all(|x| x.is_finite()));
    let ck = to_vec_f32(&out[1]).unwrap();
    assert_eq!(ck.len(), lps * spec.max_seq * spec.hkv * spec.d_head);
    // cache rows [0, c) must now be populated (nonzero), rest still zero
    let row = spec.hkv * spec.d_head;
    let first_rows = &ck[0..c * row];
    assert!(first_rows.iter().any(|&x| x != 0.0));
    let beyond = &ck[c * row..(c + 4) * row];
    assert!(beyond.iter().all(|&x| x == 0.0));
}

#[test]
fn kvp_entries_execute() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.spec;
    let (hq, dh, hkv) = (spec.hq, spec.d_head, spec.d_head * 0 + spec.hkv);
    let cap = 512usize;
    let q: Vec<f32> = (0..hq * dh).map(|i| (i as f32 * 0.01).sin()).collect();
    let k: Vec<f32> = (0..cap * hkv * dh).map(|i| (i as f32 * 0.003).cos()).collect();
    let v: Vec<f32> = (0..cap * hkv * dh).map(|i| (i as f32 * 0.007).sin()).collect();
    let out = rt
        .call(
            "kvp_partial_c1_s512",
            &[
                lit_f32(&[1, hq, dh], &q).unwrap(),
                lit_f32(&[cap, hkv, dh], &k).unwrap(),
                lit_f32(&[cap, hkv, dh], &v).unwrap(),
                lit_i32(&[1], &[599]).unwrap(), // q_start
                lit_i32(&[1], &[0]).unwrap(),   // shard_start
                lit_i32(&[1], &[512]).unwrap(), // shard_len
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 3); // (o, m, l)
    let o = to_vec_f32(&out[0]).unwrap();
    assert_eq!(o.len(), hq * dh);
    assert!(o.iter().all(|x| x.is_finite()));
    let l = to_vec_f32(&out[2]).unwrap();
    assert!(l.iter().all(|&x| x > 0.0));
}
