//! The shipped config presets under configs/ must parse and validate.

use std::path::PathBuf;

use medha::config::DeploymentConfig;

fn config_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs")
}

#[test]
fn shipped_configs_load_and_validate() {
    let mut found = 0;
    for entry in std::fs::read_dir(config_dir()).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let dep = DeploymentConfig::load(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        dep.validate()
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        found += 1;
    }
    assert!(found >= 2, "expected shipped configs, found {found}");
}

#[test]
fn llama3_8b_3d_preset_is_the_paper_layout() {
    let dep = DeploymentConfig::load(&config_dir().join("llama3_8b_3d.json")).unwrap();
    assert_eq!(dep.total_gpus(), 128);
    assert_eq!(dep.parallel.tp, 8);
    assert!(dep.scheduler.adaptive_chunking);
    assert!((dep.slo.tbt_s - 0.030).abs() < 1e-12);
}
