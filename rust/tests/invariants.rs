//! Deterministic invariant harness (seeded randomized properties via
//! `medha::util::proptest`): the structural guarantees the policy-aware
//! KVP routing and heap-backed ready-set tentpoles lean on. Slot
//! recycling must never alias a live request, KVP shard maps must cover
//! every KV token exactly once across groups, indexed ready-set selection
//! must be bit-identical to the O(n) priority scan it replaced, and
//! randomized admit/preempt/resume/finish sequences must uphold all of it
//! — under all four scheduling policies and all three routing modes.
//! The elastic-fleet tentpole extends the same guarantees across group
//! crash/recover lifecycles: a crash returns occupancy AND reservations
//! to the ledger by construction, truncated shard maps stay contiguous,
//! and re-onboarding is allowed only for lost ranges — never for
//! retained shards. The prefix-reuse tentpole adds refcount-lifecycle
//! properties: every indexed block leaves the index exactly once
//! (evicted or crash-dropped, never both, never leaked), holds are
//! released exactly once even across a crash that invalidates them, and
//! multi-turn reuse under crash/recover conserves prefill accounting.
//! Every failure reports a replay seed (`MEDHA_PROPTEST_SEED`).

use std::collections::BTreeMap;

use medha::config::{DeploymentConfig, FaultEvent, FaultKind, FaultPlan};
use medha::coordinator::{
    GroupState, KvpManager, ReadySet, Request, RequestArena, RoutingMode, SchedPolicy,
    SchedPolicyKind,
};
use medha::kvcache::{NodeRef, PrefixIndex};
use medha::sim::{SimOptions, Simulation};
use medha::util::proptest::check;
use medha::util::slotvec::SlotVec;
use medha::workload::{multiturn, MultiTurnConfig, RequestSpec};

#[test]
fn prop_arena_slot_recycling_never_aliases_live_requests() {
    check("arena recycling never aliases", 300, |rng| {
        let mut arena = RequestArena::new();
        let mut live: BTreeMap<u32, u64> = BTreeMap::new(); // slot -> ext id
        let mut next_id = 0u64;
        let mut high_water = 0usize;
        for _ in 0..rng.range_u64(1, 120) {
            if rng.bool(0.6) || live.is_empty() {
                let id = next_id;
                next_id += 1;
                let slot = arena.insert(Request::new(id, 64, 2, 0.0));
                // a handed-out slot must not collide with any live one
                assert!(live.insert(slot, id).is_none(), "slot {slot} aliased");
            } else {
                let k = rng.below(live.len() as u64) as usize;
                let (&slot, &id) = live.iter().nth(k).unwrap();
                assert_eq!(arena.remove(slot).id, id);
                live.remove(&slot);
            }
            high_water = high_water.max(live.len());
            // every live slot still resolves to exactly its own request
            for (&slot, &id) in &live {
                assert_eq!(arena.get(slot).id, id, "slot {slot} aliased");
            }
            assert_eq!(arena.len(), live.len());
        }
        // retired slots are recycled: footprint is peak concurrency
        assert!(
            arena.capacity() <= high_water.max(1),
            "arena grew to {} slots for {} peak concurrency",
            arena.capacity(),
            high_water
        );
    });
}

#[test]
fn prop_slotvec_mirrors_a_map_exactly() {
    check("slotvec mirrors map", 300, |rng| {
        let mut sv: SlotVec<u64> = SlotVec::new();
        let mut mirror: BTreeMap<usize, u64> = BTreeMap::new();
        for step in 0..rng.range_u64(1, 200) {
            let idx = rng.below(64) as usize;
            match rng.below(3) {
                0 => assert_eq!(sv.insert(idx, step), mirror.insert(idx, step)),
                1 => assert_eq!(sv.remove(idx), mirror.remove(&idx)),
                _ => assert_eq!(sv.get(idx), mirror.get(&idx)),
            }
            assert_eq!(sv.len(), mirror.len());
        }
        let got: Vec<(usize, u64)> = sv.iter().map(|(i, &v)| (i, v)).collect();
        let want: Vec<(usize, u64)> = mirror.iter().map(|(&i, &v)| (i, v)).collect();
        assert_eq!(got, want);
    });
}

#[test]
fn prop_kvp_shard_maps_cover_every_token_exactly_once() {
    check("kvp shard coverage", 200, |rng| {
        let threshold = rng.range_u64(50, 2_000);
        let n_groups = rng.range_u64(2, 8) as u32;
        let mut k = KvpManager::new(threshold, n_groups);
        let n_reqs = rng.range_u64(1, 5);
        let mut appended = vec![0u64; n_reqs as usize];
        for s in 0..n_reqs {
            k.onboard_request(s as u32, 100 + s, rng.below(n_groups as u64) as u32, 0.0);
        }
        for _ in 0..rng.range_u64(1, 60) {
            let s = rng.below(n_reqs) as u32;
            match rng.below(4) {
                0 if !k.is_yielded(s) => k.yield_active(s, 1.0),
                1 => {
                    k.resume(s, 2.0);
                }
                _ => {
                    let c = rng.range_u64(1, threshold);
                    k.append_tokens(s, c, 3.0);
                    appended[s as usize] += c;
                }
            }
            // every request's shards tile [0, total) exactly once, and
            // per-group occupancy is the sum of local shard lengths
            let mut group_sum = vec![0u64; n_groups as usize];
            for s in 0..n_reqs as u32 {
                let m = k.shard_map(s).unwrap();
                assert!(m.check_contiguous(), "shards not contiguous");
                assert_eq!(m.total_tokens(), appended[s as usize]);
                for &(g, _, n) in &m.shards {
                    group_sum[g as usize] += n;
                }
            }
            for g in 0..n_groups {
                assert_eq!(k.occupancy(g), group_sum[g as usize]);
            }
        }
        // no (request, group) pair is ever onboarded twice — yields retain
        // shards, resumes never re-onboard
        assert!(k.onboard_log_is_duplicate_free(), "a retained shard was re-onboarded");
    });
}

/// THE differential for the heap-backed ready set (PR 4 tentpole):
/// indexed selection must equal the O(n) scan under the canonical
/// `(priority, enqueue-order)` rule — across all four policies, through
/// randomized lifecycles with chunk-boundary preemption re-keys, prefill
/// completions, and arbitrary retirements. (The same equivalence is also
/// re-asserted on *every* selection inside `Scheduler::next_batch_into`
/// via a `debug_assert`, so the end-to-end lifecycle property below
/// exercises it through the full simulator for 4 policies × 3 routing
/// modes on top of this direct structural check.)
#[test]
fn prop_ready_set_selection_equals_scan() {
    check("heap selection ≡ scan", 40, |rng| {
        for kind in SchedPolicyKind::ALL {
            let policy = kind.build();
            let mut arena = RequestArena::new();
            let mut rs = ReadySet::new(policy.key_shape());
            let mut queued: Vec<u32> = Vec::new();
            let mut now = 0.0;
            for id in 0..rng.range_u64(4, 120) {
                now += rng.range_f64(0.0, 0.3);
                let roll = rng.below(12);
                if roll < 7 {
                    // admission with length-aware-ish SLO state
                    let prompt: u64 = *rng.choose(&[64, 512, 2_048, 65_536, 1_000_000]);
                    let est = prompt as f64 * rng.range_f64(1e-7, 1e-5);
                    let budget = (est * rng.range_f64(1.5, 8.0)).max(0.05);
                    let r = Request::new(id, prompt, 4, now).with_slo(est, now + budget);
                    let s = arena.insert(r);
                    rs.push(s, policy.as_ref(), &arena);
                    queued.push(s);
                } else if roll < 10 && !queued.is_empty() {
                    // the selected request runs one chunk and is re-keyed —
                    // or leaves the set when its prefill completes (the
                    // chunk boundary where a preemptive policy may switch)
                    if let Some(s) = rs.select(policy.as_ref(), &arena, now) {
                        let rem = arena.get(s).remaining_prefill();
                        let c = rng.range_u64(1, rem.max(1));
                        arena.get_mut(s).complete_chunk(c, now);
                        if arena.get(s).remaining_prefill() == 0 {
                            rs.remove(s);
                            queued.retain(|&x| x != s);
                            arena.remove(s);
                        } else {
                            rs.rekey(s, policy.as_ref(), &arena);
                        }
                    }
                } else if !queued.is_empty() {
                    // retirement of an arbitrary queued request
                    let i = rng.below(queued.len() as u64) as usize;
                    let s = queued.swap_remove(i);
                    rs.remove(s);
                    arena.remove(s);
                }
                assert_eq!(
                    rs.select(policy.as_ref(), &arena, now),
                    rs.select_via_scan(policy.as_ref(), &arena, now),
                    "{}: index diverged from scan at now={now}",
                    kind.name()
                );
                assert_eq!(rs.len(), queued.len());
            }
        }
    });
}

/// Regression for the arrival-tie admission order: two traces holding the
/// same specs in different construction order must produce identical runs
/// — the pending-admission sort tie-breaks on `(arrival_s, id)` in both
/// simulator cores, matching `workload::kvp_convoy`'s ordering, instead
/// of inheriting whatever order the trace builder emitted.
#[test]
fn same_tick_arrivals_admit_in_id_order_regardless_of_trace_order() {
    let specs = |ids: [u64; 3]| -> Vec<RequestSpec> {
        ids.iter()
            .map(|&id| RequestSpec {
                id,
                prompt_len: 256 + 64 * id, // distinct lengths expose reorders
                max_new_tokens: 4,
                arrival_s: 1.0, // all in the same tick
                ..RequestSpec::default()
            })
            .collect()
    };
    let run = |w: Vec<RequestSpec>| -> Vec<(u64, f64)> {
        let dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 2);
        let mut sim = Simulation::new(dep, w, SimOptions::default());
        sim.run();
        let mut ttfts: Vec<(u64, f64)> = sim
            .retired()
            .iter()
            .map(|r| (r.id, r.ttft().unwrap()))
            .collect();
        ttfts.sort_by(|a, b| a.0.cmp(&b.0));
        ttfts
    };
    assert_eq!(run(specs([0, 1, 2])), run(specs([2, 0, 1])));
    assert_eq!(run(specs([1, 2, 0])), run(specs([0, 1, 2])));
}

/// Randomized end-to-end lifecycle: small heterogeneous traces (Poisson
/// shorts + KVP-sharded documents) driven through the full simulator under
/// every policy, with the routing mode drawn per case. Every request must
/// finish with token-exact prefill/decode counts, every arena slot must be
/// recycled, and the onboard log must stay duplicate-free. (In debug
/// builds every selection inside these runs also differentially checks
/// the indexed ready set against the O(n) scan.)
#[test]
fn prop_random_lifecycle_upholds_invariants_across_policies() {
    check("sim lifecycle invariants", 8, |rng| {
        let n_short = rng.range_u64(4, 16);
        let mut w = Vec::new();
        let mut t = 0.0;
        for id in 0..n_short {
            t += rng.exponential(4.0);
            w.push(RequestSpec {
                id,
                prompt_len: rng.range_u64(64, 2_048),
                max_new_tokens: rng.range_u64(1, 16),
                arrival_s: t,
                ..RequestSpec::default()
            });
        }
        let n_docs = rng.range_u64(1, 3);
        for k in 0..n_docs {
            w.push(RequestSpec {
                id: n_short + k,
                prompt_len: rng.range_u64(20_000, 80_000),
                max_new_tokens: rng.range_u64(1, 8),
                arrival_s: rng.range_f64(0.0, 3.0),
                ..RequestSpec::default()
            });
        }
        let routing = *rng.choose(&[
            RoutingMode::Blind,
            RoutingMode::RoundRobin,
            RoutingMode::Routed,
        ]);
        let kvp = rng.range_u64(2, 4) as u32;
        let onboard = rng.range_u64(8_000, 40_000);
        for kind in SchedPolicyKind::ALL {
            let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, kvp);
            dep.scheduler.policy = kind;
            dep.scheduler.routing = routing;
            dep.scheduler.adaptive_chunking = false;
            dep.scheduler.static_chunk = 2048;
            dep.scheduler.kvp_onboard_threshold = onboard;
            let mut sim = Simulation::new(dep, w.clone(), SimOptions::default());
            sim.run();
            let label = format!("{}/{}", kind.name(), routing.name());
            assert_eq!(
                sim.metrics.finished_requests,
                w.len() as u64,
                "{label} left requests behind"
            );
            assert_eq!(sim.n_live(), 0, "{label} leaked arena slots");
            assert_eq!(sim.retired().len(), w.len());
            for r in sim.retired() {
                assert!(r.is_finished(), "{label}: request {} unfinished", r.id);
                assert_eq!(r.prefilled, r.prompt_len, "{label}: prefill drift on {}", r.id);
                assert_eq!(r.decoded, r.max_new_tokens, "{label}: decode drift on {}", r.id);
            }
            assert!(
                sim.kvp_onboard_log_is_duplicate_free(),
                "{label} re-onboarded a retained shard"
            );
            // active yields only exist for preemptive policies in pooled
            // modes; FCFS and blind routing must never record one
            if kind == SchedPolicyKind::Fcfs || routing == RoutingMode::Blind {
                assert_eq!(
                    sim.metrics.active_preemptions, 0,
                    "{label} yielded an active request"
                );
            }
            // capacity accounting is off by default: nothing may be refused
            assert_eq!(
                sim.metrics.routing_refusals, 0,
                "{label} refused a placement with unlimited capacity"
            );
        }
    });
}

/// Crash lifecycles at the manager level (satellite of the elastic-fleet
/// tentpole): a crash must zero the dead group's ledger — occupancy AND
/// short reservations, so the reservation leak is impossible by
/// construction — truncate every affected shard map at the last surviving
/// chunk boundary, and the exactly-once coverage property must hold
/// across recovery: re-onboarding only for dropped ranges, never for a
/// retained shard, and growth never touches the dead group again.
#[test]
fn prop_kvp_crash_recover_conserves_ledger_and_coverage() {
    check("kvp crash/recover ledger", 150, |rng| {
        let threshold = rng.range_u64(50, 1_000);
        let n_groups = rng.range_u64(3, 6) as u32;
        let mut k = KvpManager::new(threshold, n_groups);
        let n_reqs = rng.range_u64(1, 4);
        let mut total = vec![0u64; n_reqs as usize];
        for s in 0..n_reqs {
            k.onboard_request(s as u32, 100 + s, rng.below(n_groups as u64) as u32, 0.0);
        }
        // short-request reservations ride on the same ledger
        for g in 0..n_groups {
            k.reserve(g, rng.below(500));
        }
        let mut t = 1.0;
        for _ in 0..rng.range_u64(1, 50) {
            let s = rng.below(n_reqs) as u32;
            k.append_tokens(s, rng.range_u64(1, threshold), t);
            t += 1.0;
        }
        for s in 0..n_reqs as u32 {
            total[s as usize] = k.shard_map(s).unwrap().total_tokens();
        }
        let g = rng.below(n_groups as u64) as u32;
        let reserved_before = k.reserved_on(g);
        let report = k.crash_group(g, t);
        // teardown returns occupancy AND reservations in one report
        assert_eq!(report.reserved_dropped, reserved_before);
        assert_eq!(k.occupancy(g), 0, "crash left occupancy on the dead group");
        assert_eq!(k.reserved_on(g), 0, "crash leaked a short reservation");
        assert_eq!(k.state(g), GroupState::Down);
        assert!(k.ledger_is_conserved(), "crash broke occupancy conservation");
        // every affected map truncates to a contiguous prefix ending at a
        // shard boundary, with nothing left on (or after) the dead group
        let mut lost = 0u64;
        for s in 0..n_reqs as u32 {
            let m = k.shard_map(s).unwrap();
            assert!(m.check_contiguous(), "crash left a non-contiguous map");
            assert!(m.shards.iter().all(|&(gg, _, _)| gg != g));
            assert!(m.total_tokens() <= total[s as usize]);
            lost += total[s as usize] - m.total_tokens();
            total[s as usize] = m.total_tokens();
        }
        for &(vs, before, surviving) in &report.victims {
            assert!(surviving <= before);
            assert_eq!(k.shard_map(vs).unwrap().total_tokens(), surviving);
        }
        assert_eq!(
            report.victims.iter().map(|&(_, b, sv)| b - sv).sum::<u64>(),
            lost,
            "victim report disagrees with the maps"
        );
        assert!(report.occ_dropped <= lost, "dead-group drop exceeds total loss");
        // recovery: orphaned requests re-onboard on a live group (only the
        // lost ranges — the drop-aware duplicate check must allow exactly
        // this) and growth continues on the surviving fleet
        let first_active = (0..n_groups).find(|&c| k.is_placeable(c)).unwrap();
        for s in 0..n_reqs as u32 {
            if k.shard_map(s).unwrap().shards.is_empty() {
                k.release(s);
                k.onboard_request(s, 100 + s as u64, first_active, t);
            }
            let c = rng.range_u64(1, 2 * threshold);
            k.append_tokens(s, c, t);
            total[s as usize] += c;
        }
        for s in 0..n_reqs as u32 {
            let m = k.shard_map(s).unwrap();
            assert!(m.check_contiguous());
            assert_eq!(m.total_tokens(), total[s as usize], "recovery lost KV tokens");
            assert!(
                m.shards.iter().all(|&(gg, _, _)| gg != g),
                "growth re-used the dead group"
            );
        }
        assert!(
            k.onboard_log_is_duplicate_free(),
            "recovery re-onboarded a retained shard"
        );
        assert!(k.ledger_is_conserved());
    });
}

/// Randomized crash/recover lifecycles through the full simulator: a
/// group crash (sometimes followed by a warmed-up rejoin) at a random
/// instant, under all four policies × both pooled routing modes. Every
/// request must still finish with token-exact KV — total prefill work
/// equals prompt tokens plus the recomputed tokens, nothing more — the
/// drop-aware onboard log must show re-onboarding only for lost ranges,
/// and the capacity ledger must balance when the run drains.
#[test]
fn prop_crash_recover_lifecycle_across_policies() {
    check("sim crash/recover invariants", 6, |rng| {
        let n_short = rng.range_u64(4, 12);
        let mut w = Vec::new();
        let mut t = 0.0;
        for id in 0..n_short {
            t += rng.exponential(3.0);
            w.push(RequestSpec {
                id,
                prompt_len: rng.range_u64(64, 2_048),
                max_new_tokens: rng.range_u64(1, 8),
                arrival_s: t,
                ..RequestSpec::default()
            });
        }
        // an anchor document long enough that the crash instant is always
        // inside the run, plus smaller documents at random arrivals
        w.push(RequestSpec {
            id: n_short,
            prompt_len: 300_000,
            max_new_tokens: 2,
            arrival_s: 0.1,
            ..RequestSpec::default()
        });
        for kd in 0..rng.range_u64(1, 3) {
            w.push(RequestSpec {
                id: n_short + 1 + kd,
                prompt_len: rng.range_u64(30_000, 90_000),
                max_new_tokens: rng.range_u64(1, 4),
                arrival_s: rng.range_f64(0.0, 2.0),
                ..RequestSpec::default()
            });
        }
        let kvp = rng.range_u64(3, 5) as u32;
        let victim = 1 + rng.below(kvp as u64 - 1) as u32; // group 0 survives
        let crash_t = rng.range_f64(0.3, 1.5);
        let rejoin = rng.bool(0.5);
        let mut events = vec![FaultEvent {
            t_s: crash_t,
            group: Some(victim),
            kind: FaultKind::Crash,
        }];
        if rejoin {
            events.push(FaultEvent {
                t_s: crash_t + rng.range_f64(0.5, 3.0),
                group: Some(victim),
                kind: FaultKind::Join { warmup_s: 0.25 },
            });
        }
        let onboard = rng.range_u64(8_000, 40_000);
        // fault-free baseline for token conservation: processed-token
        // totals are trace properties, identical across policies/routings
        let clean_total = {
            let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, kvp);
            dep.scheduler.adaptive_chunking = false;
            dep.scheduler.static_chunk = 2048;
            dep.scheduler.kvp_onboard_threshold = onboard;
            let mut sim = Simulation::new(dep, w.clone(), SimOptions::default());
            sim.run();
            sim.metrics.prefill_tokens + sim.metrics.decode_tokens
        };
        for routing in [RoutingMode::RoundRobin, RoutingMode::Routed] {
            for kind in SchedPolicyKind::ALL {
                let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, kvp);
                dep.scheduler.policy = kind;
                dep.scheduler.routing = routing;
                dep.scheduler.adaptive_chunking = false;
                dep.scheduler.static_chunk = 2048;
                dep.scheduler.kvp_onboard_threshold = onboard;
                let opts = SimOptions {
                    faults: FaultPlan { events: events.clone() },
                    ..SimOptions::default()
                };
                let mut sim = Simulation::new(dep, w.clone(), opts);
                sim.run();
                let label =
                    format!("{}/{} crash g{victim}@{crash_t:.2}", kind.name(), routing.name());
                assert_eq!(
                    sim.metrics.finished_requests,
                    w.len() as u64,
                    "{label} left requests behind"
                );
                assert_eq!(sim.n_live(), 0, "{label} leaked arena slots");
                for r in sim.retired() {
                    assert!(r.is_finished(), "{label}: request {} unfinished", r.id);
                    assert_eq!(r.prefilled, r.prompt_len, "{label}: prefill drift on {}", r.id);
                    assert_eq!(r.decoded, r.max_new_tokens, "{label}: decode drift on {}", r.id);
                }
                assert_eq!(sim.metrics.group_crashes, 1, "{label} missed the crash");
                // KV conservation band: every recomputed token shows up
                // again in the prefill/decode counters — except that a
                // victim rewound across its prefill boundary regenerates
                // the first output token via the final prefill chunk,
                // which neither counter sees: at most one token/victim.
                let total = sim.metrics.prefill_tokens + sim.metrics.decode_tokens;
                assert!(total >= clean_total, "{label}: the crash erased processed work");
                let surplus = total - clean_total;
                let s = sim.metrics.summary();
                assert!(
                    surplus <= sim.metrics.reprefill_tokens
                        && sim.metrics.reprefill_tokens <= surplus + s.n_recovered,
                    "{label}: recomputed {} tokens for {} victims but re-processed {surplus}",
                    sim.metrics.reprefill_tokens,
                    s.n_recovered
                );
                assert!(
                    sim.kvp_onboard_log_is_duplicate_free(),
                    "{label} re-onboarded a retained shard"
                );
                assert!(sim.kvp_ledger_is_conserved(), "{label}: ledger out of balance");
                if !rejoin {
                    assert_eq!(sim.group_state(victim), GroupState::Down, "{label}");
                }
            }
        }
    });
}

/// Refcount lifecycle at the index level (prefix-reuse tentpole): a
/// random interleaving of insert / lookup+acquire / release /
/// drop_group / evict must uphold the structural invariants after every
/// step, and the block ledger must conserve exactly-once removal —
/// every newly indexed block is returned exactly once, either by
/// `evict_over_capacity` or by `drop_group`, never both and never
/// leaked. Holds invalidated by a group drop are forgotten (the sim
/// does the same after a crash); releasing only live holds means the
/// index's own double-free assertion never fires.
#[test]
fn prop_prefix_index_refcount_lifecycle() {
    check("prefix index refcount lifecycle", 200, |rng| {
        let block = *rng.choose(&[64u64, 128, 256]);
        let capacity = rng.range_u64(4, 64);
        let n_groups = rng.range_u64(2, 4) as u32;
        let mut px = PrefixIndex::new(block, capacity);
        let mut holds: Vec<NodeRef> = Vec::new();
        let (mut inserted, mut evicted, mut dropped) = (0u64, 0u64, 0u64);
        for _ in 0..rng.range_u64(20, 120) {
            match rng.below(5) {
                0 | 1 => {
                    // finished request indexes its prefix
                    let ns = rng.range_u64(1, 3);
                    let sys = *rng.choose(&[0u64, 2 * block]);
                    let tokens = rng.below(8 * block + 1);
                    let g = rng.below(n_groups as u64) as u32;
                    inserted += px.insert(ns, sys, tokens, g).new_blocks;
                }
                2 => {
                    // admission pins the deepest match
                    let ns = rng.range_u64(1, 3);
                    let sys = *rng.choose(&[0u64, 2 * block]);
                    let prompt = rng.range_u64(1, 10 * block);
                    if let Some(h) = px.lookup(ns, sys, prompt) {
                        px.acquire(h.node);
                        holds.push(h.node);
                    }
                }
                3 => {
                    // a holder finishes: exactly one release per acquire
                    if !holds.is_empty() {
                        let i = rng.below(holds.len() as u64) as usize;
                        let r = holds.swap_remove(i);
                        px.release(r);
                    }
                }
                _ => {
                    // crash: force-drop a group's chains; holds on them
                    // die with the generation bump and must be forgotten,
                    // not released (exactly-once across the crash path)
                    let g = rng.below(n_groups as u64) as u32;
                    dropped += px.drop_group(g) * block;
                    holds.retain(|&r| px.is_live(r));
                }
            }
            for (_, blocks) in px.evict_over_capacity() {
                evicted += blocks * block;
            }
            px.check_invariants().unwrap_or_else(|e| panic!("invariant broken: {e}"));
            // after eviction only pinned paths may exceed the budget, and
            // each hold pins at most one chain (inserts cap at 8 blocks)
            assert!(
                px.total_blocks() <= capacity + holds.len() as u64 * 8,
                "index grew past the budget plus its pinned chains"
            );
        }
        // drain: release every surviving hold, then drop every group.
        // Nothing may leak and nothing may be double-counted.
        for r in holds.drain(..) {
            px.release(r);
        }
        px.check_invariants().unwrap_or_else(|e| panic!("invariant broken: {e}"));
        for g in 0..n_groups {
            dropped += px.drop_group(g) * block;
        }
        assert_eq!(px.total_blocks(), 0, "blocks leaked past a full drop");
        assert_eq!(px.evictable_len(), 0, "evictable set leaked past a full drop");
        assert_eq!(
            inserted * block,
            evicted + dropped,
            "a block left the index twice or never"
        );
        px.check_invariants().unwrap();
    });
}

/// Multi-turn reuse through the full simulator under a random crash
/// (sometimes with a warmed-up rejoin): every turn still finishes with
/// token-exact prefill, the prefix index and the shared-ledger column
/// stay consistent, shared re-prefill never exceeds what was granted,
/// and a dead group that never rejoins holds no shared blocks.
#[test]
fn prop_multiturn_reuse_crash_recover_exactly_once() {
    check("multiturn reuse crash/recover", 5, |rng| {
        let cfg = MultiTurnConfig {
            n_sessions: rng.range_u64(2, 4) as usize,
            sys_prompt: *rng.choose(&[512u64, 1_024]),
            turns: rng.range_u64(2, 4) as usize,
            user_tokens: 256,
            reply_tokens: 64,
            mean_gap_s: 1.0,
            session_stagger_s: 0.5,
            shorts_rate_per_s: 2.0,
            short_prompt: 512,
            short_new_tokens: 8,
            horizon_s: 8.0,
        };
        let w = multiturn(&cfg, rng.range_u64(0, 1 << 30));
        let prompt_sum: u64 = w.iter().map(|s| s.prompt_len).sum();
        let kvp = rng.range_u64(2, 4) as u32;
        let victim = rng.below(kvp as u64) as u32;
        let crash_t = rng.range_f64(0.5, 4.0);
        let rejoin = rng.bool(0.5);
        let mut events = vec![FaultEvent {
            t_s: crash_t,
            group: Some(victim),
            kind: FaultKind::Crash,
        }];
        if rejoin {
            events.push(FaultEvent {
                t_s: crash_t + rng.range_f64(0.5, 2.0),
                group: Some(victim),
                kind: FaultKind::Join { warmup_s: 0.25 },
            });
        }
        let kind = *rng.choose(&SchedPolicyKind::ALL);
        let routing = *rng.choose(&[RoutingMode::Blind, RoutingMode::Routed]);
        let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, kvp);
        dep.scheduler.policy = kind;
        dep.scheduler.routing = routing;
        dep.scheduler.adaptive_chunking = false;
        dep.scheduler.static_chunk = 2048;
        dep.scheduler.prefix_reuse = true;
        let opts = SimOptions {
            faults: FaultPlan { events },
            ..SimOptions::default()
        };
        let mut sim = Simulation::new(dep, w.clone(), opts);
        sim.run();
        let label = format!("{}/{} reuse crash g{victim}@{crash_t:.2}", kind.name(), routing.name());
        assert_eq!(
            sim.metrics.finished_requests,
            w.len() as u64,
            "{label} left requests behind"
        );
        assert_eq!(sim.n_live(), 0, "{label} leaked arena slots");
        let mut granted = 0u64;
        for r in sim.retired() {
            assert_eq!(r.prefilled, r.prompt_len, "{label}: prefill drift on {}", r.id);
            granted += r.reused_tokens;
        }
        assert!(
            granted <= sim.metrics.prefix_hit_tokens,
            "{label}: retired requests kept more grant than was ever metered"
        );
        assert!(sim.prefix_index_is_consistent(), "{label}: prefix index inconsistent");
        assert!(sim.kvp_ledger_is_conserved(), "{label}: ledger out of balance");
        assert!(
            sim.metrics.reprefill_shared_tokens <= sim.metrics.prefix_hit_tokens,
            "{label}: re-prefilled more shared span than was ever granted"
        );
        // every prompt token was either prefilled or served from a granted
        // prefix; crashes only ever add prefill work on top
        assert!(
            sim.metrics.prefill_tokens + sim.metrics.prefix_hit_tokens >= prompt_sum,
            "{label}: prefill accounting lost prompt tokens"
        );
        if !rejoin {
            assert_eq!(sim.group_state(victim), GroupState::Down, "{label}");
            assert_eq!(
                sim.kvp_shared_on(victim),
                0,
                "{label}: dead group still holds shared blocks"
            );
        }
    });
}
