//! `cargo bench --bench paper_figures` — regenerates every table and figure
//! of the paper's evaluation (DESIGN.md §5 experiment index) and times each
//! harness. Filter with `cargo bench --bench paper_figures fig15`.

use medha::figures;
use medha::util::bench::BenchSuite;
use std::time::Instant;

fn main() {
    let mut suite = BenchSuite::from_env();
    println!("reproducing every paper table/figure; filter with --filter <id>\n");
    let mut timings = Vec::new();
    for &fig in figures::ALL_FIGURES {
        if !suite.enabled(fig) {
            continue;
        }
        let t0 = Instant::now();
        figures::run(fig).unwrap_or_else(|e| panic!("{fig}: {e}"));
        timings.push((fig, t0.elapsed().as_secs_f64()));
    }
    println!("\n=== harness timings ===");
    for (fig, t) in &timings {
        println!("{fig:<10} {t:>8.2}s");
    }
    let _ = &mut suite;
}
