//! `cargo bench --bench hotpath` — microbenchmarks of the serving hot paths
//! (L3 perf targets from DESIGN.md §7): the perf-model predictor queried by
//! adaptive chunking, scheduler batch formation, simulator iteration rate
//! on the unified pool-scheduled core, KV-cache accounting, and (when
//! artifacts exist) real PJRT execution latency for decode steps and KVP
//! partials.
//!
//! Results are recorded to `BENCH_sim.json`, including the simulator
//! throughput reports (`sim/throughput decode-stream`, `sim/million
//! mixed`), the unified-core `sim/mixed 100K-prefill + 8 decodes`
//! wall time (`sim_mixed_mean_s`), the serial-vs-threaded
//! `sim/parallel_step` comparison (`sim_parallel_speedup`), the
//! prefix-index on/off multiturn comparison (`prefix_reuse_speedup`),
//! and the concurrent policy × routing × load sweep (`sweep`, one row
//! per cell).

use medha::config::{DeploymentConfig, SloConfig};
use medha::coordinator::chunking::{AdaptiveChunk, ChunkPolicy};
use medha::coordinator::request::Request;
use medha::coordinator::scheduler::Scheduler;
use medha::coordinator::{RequestArena, SchedPolicy, StaticChunk};
use medha::kvcache::{BlockPool, KvManager};
use medha::perfmodel::{BatchShape, PerfModel};
use medha::sim::throughput::{
    decode_stream_workload, mixed_million_workload, run_sim_throughput, throughput_dep,
};
use medha::sim::{SimOptions, Simulation};
use medha::util::bench::BenchSuite;
use medha::util::json::Json;
use medha::util::rng::Rng;
use medha::workload;

fn main() {
    let mut suite = BenchSuite::from_env();
    suite.header();

    let dep = DeploymentConfig::llama3_8b_tp8();
    let pm = PerfModel::new(dep.model.clone(), dep.hardware.clone(), dep.parallel);
    let slo = SloConfig::default();

    // --- L3 scheduling hot path -----------------------------------------
    let batch = BatchShape {
        prefills: vec![medha::perfmodel::PrefillWork { chunk: 256, kv_len: 1_000_000 }],
        decodes: (0..64).map(|i| medha::perfmodel::DecodeWork { kv_len: 1_000 + i }).collect(),
    };
    suite.bench("perfmodel/iteration_time mixed-64", || {
        std::hint::black_box(pm.iteration_time(&batch));
    });

    let adaptive = AdaptiveChunk::new(vec![32, 64, 128, 256, 512, 1024, 2048, 4096]);
    let decode_ctxs: Vec<u64> = (0..64).map(|i| 1_000 + i).collect();
    suite.bench("chunking/adaptive decision (64 decodes)", || {
        std::hint::black_box(adaptive.next_chunk(
            2_000_000,
            1 << 40,
            &decode_ctxs,
            f64::INFINITY,
            &pm,
            &slo,
        ));
    });

    // 128 requests driven through prefill into steady-state decode.
    let mut requests = RequestArena::new();
    let mut sched = Scheduler::new(Box::new(StaticChunk(512)), 128);
    for id in 0..128u64 {
        let slot = requests.insert(Request::new(id, 64, 4_000, 0.0));
        sched.enqueue(slot, &requests);
        let plan = sched.next_batch(&requests, &pm, &slo, 0.0);
        sched.complete_iteration(&plan, &mut requests, 0.0);
    }
    assert_eq!(sched.n_decoding(), 128);
    let mut plan = medha::coordinator::BatchPlan::default();
    suite.bench("scheduler/next_batch 128 decodes", || {
        sched.next_batch_into(&requests, &pm, &slo, 0.0, &mut plan);
        std::hint::black_box(plan.decodes.len());
    });

    // --- ready-set selection: indexed vs O(n) scan at deep backlogs -------
    // A convoy-shaped backlog (90% interactive shorts in a few length
    // classes + 10% documents, arrivals spread so much of the queue is
    // deadline-critical) queued on one scheduler; `select` must pick the
    // same request as the scan — the differential harness asserts that —
    // so the only question benched here is the cost. Records the
    // scan-over-index ratio per backlog depth into BENCH_sim.json.
    let backlogs: &[usize] = if suite.is_smoke() {
        &[256]
    } else {
        &[1_000, 10_000, 100_000]
    };
    let mut select_rows: Vec<Json> = Vec::new();
    for kind in [
        medha::coordinator::SchedPolicyKind::Lars,
        medha::coordinator::SchedPolicyKind::Srpt,
    ] {
        let policy = kind.build();
        for &n in backlogs {
            let mut rng = Rng::new(0x5e1ec7 + n as u64);
            let mut arena = RequestArena::new();
            let mut ready = medha::coordinator::ReadySet::new(policy.key_shape());
            let now = 60.0; // arrivals span [0, 60): a deep, part-overdue queue
            for id in 0..n as u64 {
                let (prompt, est) = if id % 10 == 9 {
                    (500_000u64, 12.0)
                } else {
                    (*rng.choose(&[512u64, 1_024, 2_048]), 0.05)
                };
                let arrival = rng.range_f64(0.0, 60.0);
                let budget = est * 5.0;
                let r = Request::new(id, prompt, 8, arrival).with_slo(est, arrival + budget);
                let slot = arena.insert(r);
                ready.push(slot, policy.as_ref(), &arena);
            }
            let scan_name = format!("sched/select scan {} n={n}", kind.name());
            let index_name = format!("sched/select index {} n={n}", kind.name());
            suite.bench(&scan_name, || {
                std::hint::black_box(ready.select_via_scan(policy.as_ref(), &arena, now));
            });
            suite.bench(&index_name, || {
                std::hint::black_box(ready.select(policy.as_ref(), &arena, now));
            });
            let find = |name: &str| {
                suite.results.iter().find(|r| r.name == name).map(|r| r.mean_s)
            };
            if let (Some(scan), Some(indexed)) = (find(&scan_name), find(&index_name)) {
                let ratio = if indexed > 0.0 { scan / indexed } else { f64::NAN };
                println!(
                    "sched/select {} n={n}: scan {:.3}us vs index {:.3}us ({ratio:.0}x)",
                    kind.name(),
                    scan * 1e6,
                    indexed * 1e6
                );
                select_rows.push(Json::obj(vec![
                    ("policy", Json::str(kind.name())),
                    ("backlog", (n as u64).into()),
                    ("scan_mean_s", scan.into()),
                    ("index_mean_s", indexed.into()),
                    (
                        "scan_over_index",
                        if ratio.is_finite() { Json::num(ratio) } else { Json::Null },
                    ),
                ]));
            }
        }
    }

    suite.bench("kvcache/append+ship+release cycle", || {
        let mut kv = KvManager::new(BlockPool::new(16, 1 << 16));
        kv.onboard(1);
        for _ in 0..64 {
            kv.append(1, 128).unwrap();
            kv.account_table_shipment(&[1]);
        }
        kv.release(1).unwrap();
    });

    // --- simulator throughput: the unified pool-scheduled core ------------
    let mixed_dep = || {
        let mut dep = DeploymentConfig::llama3_8b_tp8();
        dep.scheduler.adaptive_chunking = false;
        dep.scheduler.static_chunk = 2048;
        dep
    };
    suite.bench("sim/mixed 100K-prefill + 8 decodes", || {
        let w = workload::long_plus_decodes(100_000, 8, 1_000, 64);
        let mut sim = Simulation::new(mixed_dep(), w, SimOptions::default());
        std::hint::black_box(sim.run());
    });

    let mut sim_reports: Vec<medha::sim::throughput::SimThroughput> = Vec::new();
    let smoke = suite.is_smoke();
    // 8 lockstep decoders: per-iteration cost, not perf-model volume
    let tokens_each = if smoke { 2_000 } else { 250_000 };
    suite.bench_once("sim/throughput decode-stream", || {
        let r = run_sim_throughput(
            "sim/throughput decode-stream",
            throughput_dep(1),
            decode_stream_workload(8, tokens_each),
        );
        println!("{}", r.report_line());
        sim_reports.push(r);
    });
    let (n, n_long) = if smoke { (2_000, 4) } else { (1_000_000, 200) };
    suite.bench_once("sim/million mixed", || {
        let r = run_sim_throughput(
            "sim/million mixed",
            throughput_dep(2),
            mixed_million_workload(n, n_long, 7),
        );
        println!("{}", r.report_line());
        sim_reports.push(r);
    });

    // --- parallel step: serial vs threaded wall clock ----------------------
    // The same pooled (4 KVP groups, round-robin) deployment and mixed
    // trace at threads=1 and threads=4. The sim_golden determinism suite
    // asserts the outcomes are bit-identical, so the only question here is
    // the wall-clock speedup of sharding per-group phase-A work across the
    // pool; both walls and the ratio land in BENCH_sim.json.
    let par_threads = 4usize;
    let par_dep = |threads: usize| {
        let mut dep = throughput_dep(4);
        dep.scheduler.routing = medha::coordinator::RoutingMode::RoundRobin;
        dep.scheduler.threads = threads;
        dep
    };
    let mut par_serial_wall = f64::NAN;
    let mut par_threaded_wall = f64::NAN;
    suite.bench_once("sim/parallel_step serial (threads=1)", || {
        let r = run_sim_throughput(
            "sim/parallel_step serial (threads=1)",
            par_dep(1),
            mixed_million_workload(n, n_long, 7),
        );
        println!("{}", r.report_line());
        par_serial_wall = r.wall_s;
        sim_reports.push(r);
    });
    let par_name = format!("sim/parallel_step threads={par_threads}");
    suite.bench_once(&par_name, || {
        let r = run_sim_throughput(
            &par_name,
            par_dep(par_threads),
            mixed_million_workload(n, n_long, 7),
        );
        println!("{}", r.report_line());
        par_threaded_wall = r.wall_s;
        sim_reports.push(r);
    });
    if par_serial_wall.is_finite() && par_threaded_wall.is_finite() && par_threaded_wall > 0.0 {
        println!(
            "sim/parallel_step: serial {par_serial_wall:.2}s vs {par_threads} threads \
             {par_threaded_wall:.2}s ({:.2}x)",
            par_serial_wall / par_threaded_wall
        );
    }

    // --- concurrent sweep: policy x routing x load grid --------------------
    // One independent sim per pool worker over the full grid; the Pareto
    // table goes to stdout and every cell's outcome row into
    // BENCH_sim.json's `sweep` section.
    let sweep_cfg = {
        let mut c = if smoke {
            medha::sim::sweep::SweepConfig::smoke()
        } else {
            medha::sim::sweep::SweepConfig::default()
        };
        c.threads = par_threads;
        c
    };
    let sweep_threads = sweep_cfg.threads;
    let mut sweep_rows: Vec<Json> = Vec::new();
    let mut sweep_wall = f64::NAN;
    suite.bench_once("sim/sweep policy x routing x load", || {
        let (outcomes, wall_s) = medha::sim::sweep::run_sweep(&sweep_cfg);
        medha::sim::sweep::print_table(&outcomes, wall_s, sweep_cfg.threads);
        sweep_rows = outcomes.iter().map(|o| o.to_json()).collect();
        sweep_wall = wall_s;
    });

    // --- scheduling-policy comparison on the convoy trace ------------------
    // FCFS vs LARS end-to-end on the heterogeneous workload: wall time
    // captures the policy's scheduling overhead (the priority scan +
    // preemption churn), and the recorded short-request p99 TTFT captures
    // the convoy-elimination effect itself.
    let convoy_cfg = if smoke {
        medha::workload::ConvoyConfig {
            rate_per_s: 2.0,
            horizon_s: 5.0,
            long_prompt: 32_768,
            long_every: 5,
            ..medha::workload::ConvoyConfig::default()
        }
    } else {
        medha::workload::ConvoyConfig::default()
    };
    let run_convoy = |kind: medha::coordinator::SchedPolicyKind| -> (f64, u64) {
        let sim = medha::sim::run_convoy_scenario(kind, &convoy_cfg, 42);
        let (mut short, _) = medha::sim::convoy_ttft_split(&sim, &convoy_cfg);
        (short.p99(), sim.metrics.preemptions)
    };
    let mut fcfs_p99 = f64::NAN;
    let mut lars_p99 = f64::NAN;
    let mut lars_preemptions = 0u64;
    suite.bench_once("sched/policy_compare fcfs convoy", || {
        let (p99, _) = run_convoy(medha::coordinator::SchedPolicyKind::Fcfs);
        fcfs_p99 = p99;
    });
    suite.bench_once("sched/policy_compare lars convoy", || {
        let (p99, n) = run_convoy(medha::coordinator::SchedPolicyKind::Lars);
        lars_p99 = p99;
        lars_preemptions = n;
    });
    if fcfs_p99.is_finite() && lars_p99.is_finite() {
        println!(
            "sched/policy_compare: short p99 TTFT fcfs {fcfs_p99:.3}s vs lars {lars_p99:.3}s \
             ({:.1}x, {lars_preemptions} preemptions)",
            fcfs_p99 / lars_p99
        );
    }

    // --- policy-aware KVP routing vs blind round-robin ---------------------
    // The same LARS policy with two placements on the kvp_convoy trace:
    // short p99 TTFT captures what steering shorts off the sharding groups
    // buys; active yields count the new preemption path exercised.
    let kvp_cfg = if smoke {
        medha::workload::KvpConvoyConfig {
            rate_per_s: 4.0,
            horizon_s: 5.0,
            doc_prompt: 64_000,
            n_docs: 2,
            doc_start_s: 1.0,
            doc_stagger_s: 2.0,
            ..medha::workload::KvpConvoyConfig::default()
        }
    } else {
        medha::workload::KvpConvoyConfig::default()
    };
    let run_kvp = |routing: medha::coordinator::RoutingMode| -> (f64, u64) {
        let sim = medha::sim::run_kvp_convoy_scenario(
            medha::coordinator::SchedPolicyKind::Lars,
            routing,
            &kvp_cfg,
            42,
        );
        let (mut short, _) = medha::sim::kvp_convoy_ttft_split(&sim, &kvp_cfg);
        (short.p99(), sim.metrics.active_preemptions)
    };
    let mut rr_p99 = f64::NAN;
    let mut routed_p99 = f64::NAN;
    let mut routed_yields = 0u64;
    suite.bench_once("sched/kvp_routing round-robin convoy", || {
        let (p99, _) = run_kvp(medha::coordinator::RoutingMode::RoundRobin);
        rr_p99 = p99;
    });
    suite.bench_once("sched/kvp_routing routed convoy", || {
        let (p99, n) = run_kvp(medha::coordinator::RoutingMode::Routed);
        routed_p99 = p99;
        routed_yields = n;
    });
    if rr_p99.is_finite() && routed_p99.is_finite() {
        println!(
            "sched/kvp_routing: short p99 TTFT round-robin {rr_p99:.3}s vs routed \
             {routed_p99:.3}s ({:.1}x, {routed_yields} active yields)",
            rr_p99 / routed_p99
        );
    }

    // --- prefix reuse on the multi-turn trace ------------------------------
    // LARS + cache-affinity routing with the hash-consed prefix index on
    // vs off, same seeded chat sessions: the prefill-token ratio is the
    // work the index deletes (each turn re-submits its whole history), and
    // the session-turn p95 TTFT ratio is what the user sees. Both land in
    // BENCH_sim.json as `prefix_reuse_speedup`.
    let mt_cfg = if smoke {
        medha::workload::MultiTurnConfig {
            n_sessions: 3,
            turns: 3,
            shorts_rate_per_s: 2.0,
            horizon_s: 8.0,
            ..medha::workload::MultiTurnConfig::default()
        }
    } else {
        medha::workload::MultiTurnConfig::default()
    };
    let run_reuse = |on: bool| -> (u64, u64, f64) {
        let sim = medha::sim::run_multiturn_scenario(
            medha::coordinator::SchedPolicyKind::Lars,
            medha::coordinator::RoutingMode::Routed,
            &mt_cfg,
            42,
            on,
        );
        let (_, mut turns) = medha::sim::multiturn_ttft_split(&sim, &mt_cfg);
        let p95 = turns.p95();
        (sim.metrics.prefill_tokens, sim.metrics.prefix_hit_tokens, p95)
    };
    let mut reuse_on = (0u64, 0u64, f64::NAN);
    let mut reuse_off = (0u64, 0u64, f64::NAN);
    suite.bench_once("kv/prefix_reuse on multiturn", || {
        reuse_on = run_reuse(true);
    });
    suite.bench_once("kv/prefix_reuse off multiturn", || {
        reuse_off = run_reuse(false);
    });
    if reuse_on.0 > 0 && reuse_off.0 > 0 {
        println!(
            "kv/prefix_reuse: prefill tokens {} -> {} ({:.2}x less work, {} served from \
             cache), turn p95 TTFT {:.3}s -> {:.3}s",
            reuse_off.0,
            reuse_on.0,
            reuse_off.0 as f64 / reuse_on.0 as f64,
            reuse_on.1,
            reuse_off.2,
            reuse_on.2
        );
    }

    // --- substrates -------------------------------------------------------
    let manifest_like = format!(
        "{{\"entries\":{{{}}}}}",
        (0..50)
            .map(|i| format!("\"e{i}\":{{\"file\":\"f{i}.hlo\",\"inputs\":[{{\"shape\":[16,512],\"dtype\":\"f32\"}}]}}"))
            .collect::<Vec<_>>()
            .join(",")
    );
    suite.bench("json/parse 50-entry manifest", || {
        std::hint::black_box(Json::parse(&manifest_like).unwrap());
    });

    let mut rng = Rng::new(7);
    suite.bench("rng/poisson(40) x1000", || {
        for _ in 0..1000 {
            std::hint::black_box(rng.poisson(40.0));
        }
    });

    // --- real runtime (artifacts required) --------------------------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        use medha::engine::{tokenize, Engine};
        let engine = Engine::load("artifacts", 8).unwrap();
        // warm the executable cache + state
        let mut state = engine.new_state().unwrap();
        let prompt = tokenize("benchmark prompt for decode latency measurement!!");
        engine.prefill(&mut state, &prompt, 16).unwrap();
        let mut last = vec![0i32];
        suite.bench("runtime/decode step (real PJRT, 8 layers)", || {
            let logits = engine.forward_chunk(&mut state, &last).unwrap();
            last[0] = medha::engine::argmax(&logits);
            if state.pos as usize > engine.spec.max_seq - 4 {
                state = engine.new_state().unwrap();
                engine.prefill(&mut state, &prompt, 16).unwrap();
            }
        });

        let spec = engine.spec;
        let row = spec.hkv * spec.d_head;
        let mut rng = Rng::new(3);
        let mut gen = |len: usize| -> Vec<f32> {
            (0..len).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect()
        };
        let q = gen(spec.hq * spec.d_head);
        let k = gen(1024 * row);
        let v = gen(1024 * row);
        suite.bench("runtime/kvp partial+merge (2x512)", || {
            std::hint::black_box(
                engine.kvp_decode_attention(&q, &k, &v, 1000, 512, 2).unwrap(),
            );
        });
        suite.bench("runtime/prefill chunk c=64 (8 layers)", || {
            let mut s = engine.new_state().unwrap();
            let toks: Vec<i32> = (0..64).collect();
            std::hint::black_box(engine.forward_chunk(&mut s, &toks).unwrap());
        });
    } else {
        println!("(artifacts missing — runtime benches skipped; run `make artifacts`)");
    }

    // --- record results ---------------------------------------------------
    let sim_mixed_mean_s = suite
        .results
        .iter()
        .find(|r| r.name == "sim/mixed 100K-prefill + 8 decodes")
        .map(|r| Json::num(r.mean_s))
        .unwrap_or(Json::Null);
    let num_or_null = |x: f64| if x.is_finite() { Json::num(x) } else { Json::Null };
    let extra = vec![
        ("sim_throughput", Json::arr(sim_reports.iter().map(|r| r.to_json()))),
        ("sim_mixed_mean_s", sim_mixed_mean_s),
        // scan-vs-index ready-set selection scaling (empty when filtered out)
        ("sched_select", Json::arr(select_rows)),
        (
            "sched_policy_compare",
            Json::obj(vec![
                ("workload", Json::str("convoy")),
                // Null (never bare NaN, which is invalid JSON) when the
                // convoy benches were filtered out of this run.
                ("fcfs_short_p99_ttft_s", num_or_null(fcfs_p99)),
                ("lars_short_p99_ttft_s", num_or_null(lars_p99)),
                (
                    "fcfs_over_lars",
                    if lars_p99 > 0.0 { num_or_null(fcfs_p99 / lars_p99) } else { Json::Null },
                ),
                ("lars_preemptions", lars_preemptions.into()),
            ]),
        ),
        (
            "kvp_routing",
            Json::obj(vec![
                ("workload", Json::str("kvp_convoy")),
                ("policy", Json::str("lars")),
                ("rr_short_p99_ttft_s", num_or_null(rr_p99)),
                ("routed_short_p99_ttft_s", num_or_null(routed_p99)),
                (
                    "rr_over_routed",
                    if routed_p99 > 0.0 { num_or_null(rr_p99 / routed_p99) } else { Json::Null },
                ),
                ("routed_active_yields", routed_yields.into()),
            ]),
        ),
        (
            "sim_parallel_speedup",
            Json::obj(vec![
                ("workload", Json::str("million mixed (kvp=4, round-robin)")),
                ("threads", (par_threads as u64).into()),
                ("serial_wall_s", num_or_null(par_serial_wall)),
                ("parallel_wall_s", num_or_null(par_threaded_wall)),
                (
                    "speedup",
                    if par_threaded_wall > 0.0 {
                        num_or_null(par_serial_wall / par_threaded_wall)
                    } else {
                        Json::Null
                    },
                ),
            ]),
        ),
        (
            "prefix_reuse_speedup",
            Json::obj(vec![
                ("workload", Json::str("multiturn (lars, routed affinity)")),
                ("reuse_prefill_tokens", reuse_on.0.into()),
                ("noreuse_prefill_tokens", reuse_off.0.into()),
                ("prefix_hit_tokens", reuse_on.1.into()),
                (
                    "prefill_work_ratio",
                    if reuse_on.0 > 0 {
                        num_or_null(reuse_off.0 as f64 / reuse_on.0 as f64)
                    } else {
                        Json::Null
                    },
                ),
                ("reuse_turn_p95_ttft_s", num_or_null(reuse_on.2)),
                ("noreuse_turn_p95_ttft_s", num_or_null(reuse_off.2)),
            ]),
        ),
        // One row per sweep cell (policy, routing, load, seed, goodput,
        // short p99 TTFT, deferrals, on_frontier) — empty when filtered.
        ("sweep", Json::arr(sweep_rows)),
        ("sweep_threads", (sweep_threads as u64).into()),
        ("sweep_wall_s", num_or_null(sweep_wall)),
    ];
    let out = std::path::Path::new("BENCH_sim.json");
    match suite.write_json(out, extra) {
        Ok(()) => println!("\nrecorded results to {}", out.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out.display()),
    }
}
